"""The hot-path invariant linter (repro.analysis; DESIGN.md §10).

Two halves: (a) the clean path — a real engine's registered hot paths
lint violation-free, registration/teardown works; (b) the regression
harness the acceptance criteria demand — every rule fires on a seeded
violation with correct program/rule attribution: an injected resharding
constraint, a dropped donate_argnums, an f32 upcast in a declared-bf16
program, a host callback, a non-weak scalar, an illegal tile, plus the
gateway thread-ownership lint on seeded mutations.
"""
import dataclasses
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.analysis import hlo, threads
from repro.analysis.hotpath import Budget, HotPath, Program
from repro.models.lm import ModelConfig, init
from repro.serving import SamplerConfig, ServeEngine

CFG = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab=61, remat="none", dtype="float32")

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _lint_one(fn, args, budget, rules, label="prog", name="seeded"):
    hp = HotPath(name, "test", budget, [Program(label, fn, args)])
    return hp.lint(rules=rules)


# -- clean path --------------------------------------------------------------

def test_engine_hot_paths_lint_clean():
    """A real engine registers at construction, its declared program
    families pass every rule, and close() deregisters it."""
    params = init(CFG, jax.random.PRNGKey(0))
    before = len(analysis.registered())
    eng = ServeEngine(CFG, params, max_batch=2, max_len=32, drain_steps=2,
                      sampler=SamplerConfig(temperature=0.0))
    try:
        assert len(analysis.registered()) == before + 1
        hps = eng.hot_paths()
        assert {hp.name for hp in hps} == {"lm.prefill", "lm.admit",
                                           "lm.decode"}
        violations = analysis.lint_hot_paths(hps)
        assert not violations, analysis.format_report(violations)
    finally:
        eng.close()
    assert len(analysis.registered()) == before


def test_unknown_rule_name_raises():
    hp = HotPath("x", "test", Budget(), [])
    with pytest.raises(KeyError, match="no-such-rule"):
        hp.lint(rules=("no-such-rule",))


# -- seeded violations: one per rule ----------------------------------------

@needs8
def test_seeded_resharding_constraint_fires_collective_budget():
    """An injected replication constraint on a 'model'-sharded operand
    forces a weight-sized all-gather into the program — the collective
    budget rule must catch exactly that."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_serve_mesh

    mesh = make_serve_mesh(2)
    shard = NamedSharding(mesh, P(None, "model"))
    repl = NamedSharding(mesh, P())

    w = jax.device_put(jnp.ones((256, 64), jnp.float32), shard)

    @jax.jit
    def bad(w):
        # the injected resharding constraint: gathers all 64 KiB of w
        return jax.lax.with_sharding_constraint(w, repl).sum()

    v = _lint_one(bad, (w,), Budget(max_gather_bytes=16384),
                  rules=("collective-budget",), name="lm.decode-seeded")
    assert v, "injected resharding produced no violation"
    assert all(x.rule == "collective-budget" for x in v)
    assert v[0].program == "lm.decode-seeded:prog"
    assert "all-gather" in v[0].message


def test_seeded_scan_flatness_violation_fires():
    """A collective inside the scan body shows n x the textual count at
    drain length n — flatness across the family must fail. Driven on
    injected HLO texts so the counting logic is pinned on 1 device."""
    one = '%ag = f32[8,16] all-gather(%p0), dimensions={0}\n'
    hp = HotPath("lm.decode-seeded", "test",
                 Budget(max_gather_bytes=None, scan_flat=True),
                 [Program("n=1", None, (), text=one),
                  Program("n=8", None, (), text=one * 8)])
    v = hp.lint(rules=("collective-budget",))
    assert len(v) == 1 and v[0].rule == "collective-budget"
    assert v[0].program == "lm.decode-seeded:*"
    assert "not flat" in v[0].message


def test_seeded_all_to_all_budget_fires():
    txt = "%a2a = f32[8,16] all-to-all(%p0), dimensions={0}\n"
    hp = HotPath("x", "test", Budget(), [Program("p", None, (), text=txt)])
    v = hp.lint(rules=("collective-budget",))
    assert len(v) == 1 and "all-to-all" in v[0].message


def test_seeded_dropped_donation_fires():
    """Budget declares argnum 0 donated, but the jit dropped its
    donate_argnums — no alias in the executable, rule fires."""
    x = jnp.arange(8, dtype=jnp.float32)

    honored = jax.jit(lambda s: s + 1.0, donate_argnums=(0,))
    assert not _lint_one(honored, (x,), Budget(donate=(0,)),
                         rules=("donation-honored",))

    dropped = jax.jit(lambda s: s + 1.0)   # the seeded bug
    v = _lint_one(dropped, (jnp.arange(8, dtype=jnp.float32),),
                  Budget(donate=(0,)), rules=("donation-honored",),
                  name="lm.prefill-seeded")
    assert len(v) == 1
    assert v[0].rule == "donation-honored"
    assert v[0].program == "lm.prefill-seeded:prog"
    assert "not aliased" in v[0].message


def test_seeded_f32_upcast_in_bf16_region_fires():
    a = jnp.ones((8, 16), jnp.bfloat16)
    b = jnp.ones((16, 8), jnp.bfloat16)

    @jax.jit
    def upcast(a, b):   # the seeded bug: f32 matmul inside bf16 region
        return jnp.dot(a.astype(jnp.float32),
                       b.astype(jnp.float32)).astype(jnp.bfloat16)

    v = _lint_one(upcast, (a, b), Budget(compute_dtype="bf16"),
                  rules=("dtype-discipline",), name="bf16-region")
    assert any(x.rule == "dtype-discipline" and "f32" in x.message
               for x in v), v

    @jax.jit
    def clean(a, b):
        return jnp.dot(a, b)

    assert not _lint_one(clean, (a, b), Budget(compute_dtype="bf16"),
                         rules=("dtype-discipline",))


def test_seeded_plane_float_convert_fires():
    planes = jnp.ones((2, 8, 4), jnp.uint32)

    touched = jax.jit(lambda p: p.astype(jnp.float32).sum())
    v = _lint_one(touched, (planes,), Budget(),
                  rules=("dtype-discipline",), name="planes")
    assert len(v) == 1 and v[0].rule == "dtype-discipline"
    assert "uint32 plane" in v[0].message

    # bitwise plane use (the real dataflow) stays clean; so does a PRNG
    # key (1-d u32) flowing into float sampling
    bitwise = jax.jit(lambda p: jnp.sum(p & 0xF))
    assert not _lint_one(bitwise, (planes,), Budget(),
                         rules=("dtype-discipline",))
    sample = jax.jit(lambda k: jax.random.uniform(k, (4,)))
    assert not _lint_one(sample, (jax.random.PRNGKey(0),), Budget(),
                         rules=("dtype-discipline",))


def test_seeded_f64_fires_on_text():
    txt = "%w = f64[4,4] parameter(0)\n"
    hp = HotPath("x", "test", Budget(max_gather_bytes=None),
                 [Program("p", None, (), text=txt)])
    v = hp.lint(rules=("dtype-discipline",))
    # text-only program has no jaxpr; dtype rule must flag f64 before
    # needing one
    assert any("f64" in x.message for x in v)


def test_seeded_host_callback_fires():
    x = jnp.arange(4, dtype=jnp.float32)

    @jax.jit
    def chatty(x):   # the seeded bug: host round-trip per step
        jax.debug.callback(lambda v: None, x[0])
        return x * 2.0

    v = _lint_one(chatty, (x,), Budget(), rules=("no-host-sync",),
                  name="lm.decode-seeded")
    assert v and all(x.rule == "no-host-sync" for x in v)
    assert v[0].program == "lm.decode-seeded:prog"
    assert "callback" in v[0].message

    assert not _lint_one(jax.jit(lambda x: x * 2.0), (x,), Budget(),
                         rules=("no-host-sync",))


def test_seeded_nonweak_scalar_fires():
    fn = jax.jit(lambda x, t: x * t)
    x = jnp.arange(4, dtype=jnp.float32)

    v = _lint_one(fn, (x, np.float32(0.5)), Budget(),
                  rules=("recompile-hazard",), name="sampler")
    assert len(v) == 1 and v[0].rule == "recompile-hazard"
    assert v[0].program == "sampler:prog"
    assert "numpy scalar" in v[0].message

    # python scalars are weakly typed — the shared-program case
    assert not _lint_one(fn, (x, 0.5), Budget(),
                         rules=("recompile-hazard",))
    # committed 0-d device scalars fork the cache per dtype too
    v = _lint_one(fn, (x, jnp.float32(0.5)), Budget(),
                  rules=("recompile-hazard",))
    assert len(v) == 1 and "0-d" in v[0].message


def test_seeded_illegal_tile_fires():
    from repro.core.packed import TuneDecision, prepack

    rng = np.random.default_rng(0)
    pw = prepack(jnp.asarray(rng.standard_normal((64, 16)), jnp.float32), 4)
    bad = dataclasses.replace(pw, tune=TuneDecision(backend="pallas",
                                                    bm=3, bn=7))
    v = _lint_one(None, ({"w": bad},),
                  Budget(m_hint=8, pallas_ok=False),
                  rules=("tile-legality",), name="cnn.fwd-seeded")
    rules_fired = sorted(x.rule for x in v)
    assert rules_fired and set(rules_fired) == {"tile-legality"}
    msgs = " | ".join(x.message for x in v)
    assert "pallas" in msgs                 # pallas under a mesh
    assert "bm=3" in msgs and "bn=7" in msgs    # non-dividing tiles
    assert v[0].program == "cnn.fwd-seeded:prog"

    good = dataclasses.replace(pw, tune=TuneDecision(backend="popcount",
                                                     bm=4, bn=8, bkw=1))
    assert not _lint_one(None, ({"w": good},), Budget(m_hint=8),
                         rules=("tile-legality",))


# -- shared helpers stay the single source of truth -------------------------

def test_gather_sizes_and_counts_pinned():
    txt = ("%ag = f32[8,64] all-gather(%p0), dimensions={0}\n"
           "%ar = bf16[4] all-reduce(%x), to_apply=%add\n"
           "%cp = u32[2,2] collective-permute(%y)\n")
    assert hlo.gather_sizes(txt) == [8 * 64 * 4]
    assert hlo.collective_counts(txt) == {
        "all-gather": 1, "all-reduce": 1, "all-to-all": 0,
        "collective-permute": 1}


def test_input_output_alias_parse_pinned():
    hdr = ("HloModule jit_f, is_scheduled=true, input_output_alias={ "
           "{0}: (0, {}, may-alias), {1}: (3, {}, may-alias) }, "
           "entry_computation_layout={(f32[4]{0})->f32[4]{0}}\n")
    assert hlo.input_output_aliases(hdr) == {0, 3}
    assert hlo.input_output_aliases("HloModule jit_f\n") == set()


# -- gateway thread-ownership lint ------------------------------------------

def test_gateway_module_passes_thread_lint():
    assert threads.check_gateway() == []


_BAD_GATEWAY = textwrap.dedent("""
    class Gateway:
        async def submit_lm(self, prompt):
            self._lm.validate(prompt, 4)        # read-only: allowed
            self._lm.submit(prompt)             # mutation on asyncio thread
            return self._enqueue(prompt)

        def _enqueue(self, prompt):
            self._lm.drain_steps = 2            # attribute store

        def stats(self):
            return self._lm.health              # read: allowed

        def _lm_worker(self):
            self._lm.submit(None)               # worker-side: allowed
            self._lm.step()
""")


def test_seeded_gateway_mutations_fire_thread_lint():
    v = threads.check_source(_BAD_GATEWAY, filename="seeded.py")
    assert all(x.rule == "thread-ownership" for x in v)
    msgs = {x.program: x.message for x in v}
    assert any("submit_lm" in p and ".submit()" in m
               for p, m in msgs.items()), v
    assert any("_enqueue" in p and "drain_steps" in m
               for p, m in msgs.items()), v
    # worker-side mutations and read-only loop-side access never flagged
    assert not any("_lm_worker" in p for p in msgs)
    assert len(v) == 2


def test_thread_lint_ignores_deferred_closures():
    src = textwrap.dedent("""
        class Gateway:
            def start(self):
                def run():
                    self._lm.step()     # executes on the worker thread
                self._spawn(run)
    """)
    assert threads.check_source(src) == []
