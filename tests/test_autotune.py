"""Autotuner (repro.pim.autotune): backend-equivalence matrix, tuning-cache
robustness, checkpoint round-trip, and engine integration.

The core contract: tuning redirects *dispatch only*. Whatever backend and
tiles the autotuner picks, the integer product P is bit-identical (mod
2^32) to every backend it didn't pick — asserted across the full candidate
set including prime-N and bn%128≠0 shapes. The cache is fail-safe: any
unusable file (corrupt, truncated, stale schema or kernel version)
degrades to fresh cost-model picks with one warning — never a crash,
never a per-call retune storm.
"""
import dataclasses
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitserial import int_matmul_prepacked
from repro.core.packed import PackedWeight, TuneDecision, prepack
from repro.pim import autotune as at

# Deliberately awkward shapes: prime K/N, N below one lane group, N just
# over the popcount column chunk (bn % 128 != 0 on the pallas path).
SHAPES = [(4, 64, 128), (5, 67, 33), (8, 96, 130)]
BITS = [2, 4, 8]


def _operands(m, k, n, bits, seed=0):
    key = jax.random.PRNGKey(seed)
    qa = jax.random.randint(key, (m, k), 0, 2 ** bits, jnp.int32)
    pk = prepack(jax.random.normal(jax.random.fold_in(key, 1), (k, n)), bits)
    return qa, pk


# ---------------------------------------------------------------------------
# Backend-equivalence matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("bits", BITS)
def test_autotuned_output_bit_identical_across_candidates(m, k, n, bits):
    """Every candidate decision — all four backends, every legalized pallas
    tile — computes the identical P; the pick can affect speed only."""
    qa, pk = _operands(m, k, n, bits)
    ref = np.asarray(int_matmul_prepacked(qa, pk, bits, "popcount"))
    cands = at.gemm_candidates(m, k, n, bits, bits, backends=at.ALL_BACKENDS)
    assert {d.backend for d in cands} == set(at.ALL_BACKENDS)
    for d in cands:
        out = np.asarray(int_matmul_prepacked(qa, at.attach(pk, d), bits))
        assert np.array_equal(ref, out), f"backend mismatch for {d}"


def test_decision_overrides_config_backend():
    """An attached decision wins over the call-site backend argument."""
    qa, pk = _operands(4, 64, 128, 4)
    tuned = at.attach(pk, TuneDecision(backend="int-direct"))
    ref = np.asarray(int_matmul_prepacked(qa, pk, 4, "popcount"))
    out = np.asarray(int_matmul_prepacked(qa, tuned, 4, "popcount"))
    assert np.array_equal(ref, out)
    assert tuned.tune.backend == "int-direct"


def test_decision_is_static_metadata():
    """Attaching a decision changes no leaves — shardings, donation and
    checkpoint layouts are untouched; only the treedef differs."""
    _, pk = _operands(4, 64, 128, 4)
    tuned = at.attach(pk, TuneDecision(backend="pallas", bm=8, bn=128))
    for a, b in zip(jax.tree_util.tree_leaves(pk),
                    jax.tree_util.tree_leaves(tuned)):
        assert a is b
    assert (jax.tree_util.tree_structure(pk)
            != jax.tree_util.tree_structure(tuned))


# ---------------------------------------------------------------------------
# Cache robustness
# ---------------------------------------------------------------------------

def _count_ranks(monkeypatch):
    calls = {"n": 0}
    real = at.gemm_candidates

    def counted(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(at, "gemm_candidates", counted)
    return calls


@pytest.mark.parametrize("blob", [
    "{ this is not json",                       # corrupt
    '{"version": 1, "code_version": "x", "ent', # truncated
    json.dumps({"version": 99, "code_version": "x", "entries": {}}),  # schema
    json.dumps({"version": 1, "code_version": "stale", "entries": {}}),
])
def test_unusable_cache_falls_back_with_single_warning(tmp_path, blob,
                                                       monkeypatch):
    path = tmp_path / "tune.json"
    path.write_text(blob)
    calls = _count_ranks(monkeypatch)
    with pytest.warns(RuntimeWarning, match="falling back to cost-model"):
        cache = at.TuningCache(str(path))
    # Fallback picks still happen — and each key ranks exactly once (the
    # in-memory memo absorbs repeats: no retune storm after a bad load).
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # a second warning would fail
        d1 = at.decide_gemm(4, 64, 128, 4, 4, cache=cache,
                            hlo_tiebreak=False)
        for _ in range(5):
            assert at.decide_gemm(4, 64, 128, 4, 4, cache=cache,
                                  hlo_tiebreak=False) == d1
    assert calls["n"] == 1
    # The next save self-heals the file: a fresh cache loads it cleanly.
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        fresh = at.TuningCache(str(path))
    assert fresh.get(at.gemm_key(4, 64, 128, 4, 4, at.XLA_BACKENDS)) == d1


def test_cache_persists_and_round_trips(tmp_path):
    path = str(tmp_path / "tune.json")
    c1 = at.TuningCache(path)
    d = at.decide_gemm(8, 96, 130, 8, 8, cache=c1, hlo_tiebreak=False)
    c2 = at.TuningCache(path)
    assert c2.get(at.gemm_key(8, 96, 130, 8, 8, at.XLA_BACKENDS)) == d
    blob = json.load(open(path))
    assert blob["version"] == at.TuningCache.VERSION
    assert blob["code_version"] == at.code_version()


def test_cache_checkpoint_round_trip(tmp_path):
    """Decisions survive training/checkpoint.py's manifest extra dict."""
    from repro.training import checkpoint as ckpt

    cache = at.TuningCache(None)
    d = at.decide_gemm(4, 64, 128, 4, 4, cache=cache, hlo_tiebreak=False)
    tree = {"w": jnp.zeros((2, 2))}
    ckpt.save(str(tmp_path), 0, tree, extra={"tuning": cache.to_extra()})
    _, manifest = ckpt.restore(str(tmp_path), tree)
    fresh = at.TuningCache(None)
    fresh.merge_extra(manifest["extra"]["tuning"])
    assert fresh.get(at.gemm_key(4, 64, 128, 4, 4, at.XLA_BACKENDS)) == d


def test_reset_reloads_repaired_file_and_rearms_warning(tmp_path):
    """The single-warning fallback memo used to stick for the instance
    lifetime: a cache that degraded on a corrupt file kept serving the
    empty memo — silently — even after the file on disk was repaired.
    ``reset()`` drops the memo and re-reads the backing file."""
    path = str(tmp_path / "tune.json")
    good = at.TuningCache(path)
    d = at.decide_gemm(4, 64, 128, 4, 4, cache=good, hlo_tiebreak=False)
    key = at.gemm_key(4, 64, 128, 4, 4, at.XLA_BACKENDS)
    blob = open(path).read()

    open(path, "w").write("{ corrupt")
    with pytest.warns(RuntimeWarning, match="falling back"):
        cache = at.TuningCache(path)
    assert cache.get(key) is None and cache._warned

    open(path, "w").write(blob)        # repair on disk
    assert cache.get(key) is None      # stale memo: still empty, still silent
    cache.reset()
    assert cache.get(key) == d         # repaired file actually reloaded
    assert not cache._warned           # and the fallback warning is re-armed


def test_engine_close_resets_shared_cache(tmp_path):
    """Engine teardown resets its tuning cache, so a second deploy sharing
    the cache object reloads the (self-healed) backing file instead of
    serving the stale degraded memo."""
    from repro.serving import ServeEngine

    path = str(tmp_path / "tune.json")
    open(path, "w").write("{ corrupt")
    with pytest.warns(RuntimeWarning, match="falling back"):
        cache = at.TuningCache(path)
    cfg, params = _lm_setup()
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32,
                      autotune="cost", tuning_cache=cache)
    assert eng.tune_cache is cache
    assert len(cache) > 0              # tuning self-healed the file on save
    eng.close()
    assert not cache._warned           # close() re-armed the fallback path
    assert len(cache) > 0              # reload picked up the healed file


def test_stale_snapshot_extra_dropped_with_warning():
    cache = at.TuningCache(None)
    with pytest.warns(RuntimeWarning, match="falling back"):
        cache.merge_extra({"version": 1, "code_version": "stale",
                           "entries": {}})
    assert len(cache) == 0


def test_measure_mode_uses_injected_measurer():
    times = {"popcount": 3.0, "mxu-plane": 2.0, "int-direct": 1.0}
    d = at.decide_gemm(8, 256, 256, 4, 4, mode="measure",
                       measure=lambda dec, *a: times[dec.backend],
                       hlo_tiebreak=False)
    assert d.backend == "int-direct"
    # A measurer that fails everywhere degrades to the analytic pick.
    d2 = at.decide_gemm(8, 256, 256, 4, 4, mode="measure",
                        measure=lambda dec, *a: None, hlo_tiebreak=False)
    assert d2.backend in at.XLA_BACKENDS


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------

def _lm_setup():
    from repro.core.pim_layers import PIMQuantConfig
    from repro.models.lm import ModelConfig, init

    pim = PIMQuantConfig(w_bits=4, a_bits=4, backend="popcount",
                         enabled=True)
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                      d_ff=64, vocab=51, remat="none", dtype="float32",
                      pim=pim)
    return cfg, init(cfg, jax.random.PRNGKey(0))


def _decode_tokens(eng):
    from repro.serving import Request

    eng.submit(Request(rid=0, prompt=np.array([3, 1, 4, 1, 5], np.int32),
                       max_new_tokens=6))
    return eng.run()[0].tokens


def test_serve_engine_autotune_token_parity(tmp_path):
    from repro.serving import ServeEngine

    cfg, params = _lm_setup()
    base = _decode_tokens(ServeEngine(cfg, params, max_batch=2, max_len=64))
    path = str(tmp_path / "tune.json")
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                      autotune="cost", tuning_cache=path)
    assert _decode_tokens(eng) == base
    leaves = [l for l in jax.tree_util.tree_leaves(
        eng.params, is_leaf=lambda x: isinstance(x, PackedWeight))
        if isinstance(l, PackedWeight)]
    assert leaves and all(l.tune is not None for l in leaves)
    assert os.path.exists(path) and len(eng.tune_cache) > 0


def test_serve_engine_redeploy_retunes(tmp_path):
    from repro.serving import ServeEngine

    cfg, params = _lm_setup()
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                      autotune="cost", keep_masters=True)
    n0 = len(eng.tune_cache)
    eng.redeploy(dataclasses.replace(cfg.pim, w_bits=8, a_bits=8))
    assert len(eng.tune_cache) > n0      # new precision, new decisions
    leaves = [l for l in jax.tree_util.tree_leaves(
        eng.params, is_leaf=lambda x: isinstance(x, PackedWeight))
        if isinstance(l, PackedWeight)]
    assert all(l.tune is not None for l in leaves)


def test_serve_engine_snapshot_carries_tuning(tmp_path):
    from repro.serving import ServeEngine

    cfg, params = _lm_setup()
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64, autotune="cost")
    eng.snapshot(str(tmp_path), step=1)
    eng2 = ServeEngine(cfg, params, max_batch=2, max_len=64,
                       autotune="cost")
    manifest = eng2.restore(str(tmp_path))
    assert "tuning" in manifest["extra"]
    assert len(eng2.tune_cache) >= len(eng.tune_cache)


def test_vision_engine_autotune_parity(tmp_path):
    from repro.models.cnn import alexnet
    from repro.serving.vision import VisionEngine, VisionRequest

    key = jax.random.PRNGKey(0)
    params = alexnet.init(key, num_classes=10, image=64)
    imgs = [np.asarray(jax.random.normal(jax.random.fold_in(key, i),
                                         (64, 64, 3))) for i in range(4)]

    def run(engine):
        for i, im in enumerate(imgs):
            engine.submit(VisionRequest(rid=i, image=im, model="alexnet",
                                        precision="<4:4>"))
        return [c.logits for c in engine.run()]

    base = run(VisionEngine({"alexnet": params}, backend="int-direct",
                            max_batch=4))
    path = str(tmp_path / "tune.json")
    ve = VisionEngine({"alexnet": params}, backend="int-direct",
                      max_batch=4, autotune="cost", tuning_cache=path)
    got = run(ve)
    for a, b in zip(base, got):
        assert np.allclose(a, b, atol=1e-4)
    assert len(ve.tune_cache) > 0 and os.path.exists(path)
    assert ve._tuned                     # tuned tree derived at dispatch


# ---------------------------------------------------------------------------
# Mesh (tier1-mesh8 job)
# ---------------------------------------------------------------------------

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@needs8
def test_tuned_picks_respect_mesh_sharding():
    """Autotuned serving on the (data=4, model=2) mesh: decisions exclude
    pallas (no GSPMD rule), the committed bank-split layouts are untouched,
    and decode tokens match the untuned mesh engine."""
    from repro.launch.mesh import make_serve_mesh
    from repro.serving import ServeEngine

    cfg, params = _lm_setup()
    mesh = make_serve_mesh(2)
    base = _decode_tokens(ServeEngine(cfg, params, max_batch=2, max_len=64,
                                      mesh=mesh))
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64, mesh=mesh,
                      autotune="cost")
    assert _decode_tokens(eng) == base
    leaves = [l for l in jax.tree_util.tree_leaves(
        eng.params, is_leaf=lambda x: isinstance(x, PackedWeight))
        if isinstance(l, PackedWeight)]
    assert leaves
    for l in leaves:
        assert l.tune is not None and l.tune.backend != "pallas"
        # The decision wrapped the committed shards as-is: the planes
        # still carry their bank-split (or guarded-replicated) sharding.
        assert l.planes.sharding is not None
