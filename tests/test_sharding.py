"""Sharding rules + a miniature multi-device dry-run.

The production 512-device dry-run lives in repro.launch.dryrun (and its
results in results/dryrun/). Here we verify the *rules*: spec construction,
divisibility guards, MoE expert-vs-ffn fallback, and an actual 8-device
lower+compile in a subprocess (the main test process must stay at 1 device
so smoke tests see an unsharded world)."""
import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as sh
from repro.models.lm import abstract_params


def _mesh_stub(shape, names):
    """A Mesh over 1 real device can't have size>1 — use jax.sharding.Mesh
    abstract construction via AbstractMesh for spec-only tests.

    AbstractMesh's signature changed across jax versions: 0.4.x takes one
    ((name, size), ...) shape tuple; >=0.5 takes (sizes, names)."""
    try:
        return jax.sharding.AbstractMesh(tuple(zip(names, shape)))
    except TypeError:
        return jax.sharding.AbstractMesh(shape, names)


def test_param_specs_dense():
    mesh = _mesh_stub((16, 16), ("data", "model"))
    cfg = get_config("llama3.2-3b").model
    tree = abstract_params(cfg)
    # llama3.2-3b ties embeddings: vocab stays on the TP axis (lm_head use)
    sh.set_tied_embeddings(True)
    specs = jax.tree_util.tree_map_with_path(
        lambda p, l: sh._param_spec(p, l, mesh, None), tree)
    assert specs["embed"] == P("model", "data")
    # untied models shard vocab on FSDP only (cheap token gather)
    sh.set_tied_embeddings(False)
    specs = jax.tree_util.tree_map_with_path(
        lambda p, l: sh._param_spec(p, l, mesh, None), tree)
    assert specs["embed"] == P(None, "data")
    blk = specs["scan"][0]
    assert blk["attn"]["wq"] == P(None, "data", "model")
    assert blk["attn"]["wo"] == P(None, "model", "data")
    assert blk["ffn"]["w_in"] == P(None, "data", "model")
    assert blk["ffn"]["w_out"] == P(None, "model", "data")
    assert blk["norm1"]["scale"] == P(None, None)  # stacked, replicated


def test_param_specs_moe_expert_parallel_vs_tp():
    mesh = _mesh_stub((16, 16), ("data", "model"))
    phi = get_config("phi3.5-moe-42b-a6.6b").model   # 16 experts: EP
    tree = jax.tree_util.tree_map_with_path(
        lambda p, l: sh._param_spec(p, l, mesh, None), abstract_params(phi))
    assert tree["scan"][0]["ffn"]["w_in"] == P(None, "model", "data", None)
    grok = get_config("grok-1-314b").model            # 8 experts: TP inside
    tree = jax.tree_util.tree_map_with_path(
        lambda p, l: sh._param_spec(p, l, mesh, None), abstract_params(grok))
    assert tree["scan"][0]["ffn"]["w_in"] == P(None, None, "data", "model")


def test_divisibility_guard_drops_axes():
    mesh = _mesh_stub((16, 16), ("data", "model"))
    # vocab 49155 = 3*5*29*113 is not divisible by 16 -> replicated
    cfg = get_config("granite-3-2b").model
    tree = abstract_params(cfg)
    specs = jax.tree_util.tree_map_with_path(
        lambda p, l: sh._param_spec(p, l, mesh, None), tree)
    assert specs["embed"] == P(None, "data")


def test_divisibility_guard_warns_once(caplog):
    """A dropped rule axis must be visible (warn), but exactly once per
    (leaf, axis, dim) — the guard runs per tree leaf, so an unthrottled
    warning would flood a misconfigured-mesh launch."""
    mesh = _mesh_stub((16, 16), ("data", "model"))
    sh.reset_drop_warnings()
    with caplog.at_level("WARNING", logger="repro.distributed.sharding"):
        spec = sh._guard(("model",), (61,), mesh, label="serve-param:head")
        assert spec == P(None)
        sh._guard(("model",), (61,), mesh, label="serve-param:head")  # dup
    drops = [r for r in caplog.records if "dropping to replication" in r.message]
    assert len(drops) == 1, [r.message for r in drops]
    assert "serve-param:head" in drops[0].message
    with caplog.at_level("WARNING", logger="repro.distributed.sharding"):
        caplog.clear()
        # axis of size 1 (or absent) is not a misconfiguration: no warning
        sh._guard(("model",), (61,), _mesh_stub((16, 1), ("data", "model")),
                  label="x")
        sh._guard(("missing",), (61,), mesh, label="x")
    assert not [r for r in caplog.records
                if "dropping to replication" in r.message]
    sh.reset_drop_warnings()


def test_multipod_fsdp_spans_pods():
    mesh = _mesh_stub((2, 16, 16), ("pod", "data", "model"))
    cfg = get_config("llama3.2-3b").model
    specs = jax.tree_util.tree_map_with_path(
        lambda p, l: sh._param_spec(p, l, mesh, None), abstract_params(cfg))
    assert specs["scan"][0]["attn"]["wq"] == P(None, ("pod", "data"), "model")


def test_batch_spec_fallbacks():
    mesh = _mesh_stub((16, 16), ("data", "model"))
    assert sh.batch_spec(mesh, 256) == P(("data",), None)
    assert sh.batch_spec(mesh, 1) == P(None, None)   # long_500k B=1


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from functools import partial
from repro.configs import get_config
from repro.distributed import sharding as sh
from repro.models.lm import init as minit, loss_fn
from repro.models.lm.model import cast_params
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import make_train_step

mesh = jax.make_mesh((4, 2), ("data", "model"))
sh.set_mesh(mesh)
cfg = get_config("qwen3-0.6b").model.reduced(vocab=512, d_model=128)
params = cast_params(minit(cfg, jax.random.PRNGKey(0)), jnp.bfloat16)
p_sh = sh.param_shardings(params, mesh)
params = jax.device_put(params, p_sh)
ocfg = OptimizerConfig(warmup_steps=1, total_steps=10)
opt = init_opt_state(ocfg, params)
o_sh = sh.param_shardings(opt, mesh); o_sh["step"] = sh.replicated(mesh)
opt = jax.device_put(opt, o_sh)
batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
         "labels": jnp.zeros((8, 32), jnp.int32)}
b_sh = sh.batch_shardings(batch, mesh, 8)
batch = jax.device_put(batch, b_sh)
step = jax.jit(make_train_step(cfg, ocfg), in_shardings=(p_sh, o_sh, b_sh),
               out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
params, opt, m = step(params, opt, batch)
params, opt, m = step(params, opt, batch)
print(json.dumps({"loss": float(m["loss"]), "ok": bool(jnp.isfinite(m["loss"]))}))
"""


def test_real_8device_sharded_train_step():
    """End-to-end sharded train step on an actual 4x2 CPU mesh."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"], res


def test_dryrun_results_exist_and_pass():
    """The committed dry-run artifacts cover all 40 cells on both meshes."""
    d = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                     "results", "dryrun")
    if not os.path.isdir(d) or len(os.listdir(d)) < 80:
        pytest.skip("dry-run sweep not yet complete")
    bad = []
    for f in os.listdir(d):
        if not f.endswith(".json"):
            continue
        with open(os.path.join(d, f)) as fh:
            r = json.load(fh)
        if "skipped" not in r and "roofline" not in r:
            bad.append(f)
    assert not bad, bad
