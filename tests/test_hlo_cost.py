"""Pin the roofline HLO cost model (repro.roofline.hlo_cost) on hand-written
fixtures with known arithmetic: dot flops, conv flops, collective byte/count
accounting, and the while-loop trip-count multiplication that is the whole
point of the module (``cost_analysis()`` visits scan bodies once).

The fixtures follow post-optimization HLO text syntax — the same format the
parser sees from ``compiled.as_text()``; tests elsewhere exercise it on real
dumps, here the expected numbers are computable by hand.
"""
from repro.roofline.hlo_cost import analyze

_MATMUL = """\
HloModule m

ENTRY %main (p0: f32[8,64], p1: f32[64,32]) -> f32[8,32] {
  %p0 = f32[8,64] parameter(0)
  %p1 = f32[64,32] parameter(1)
  ROOT %dot.1 = f32[8,32] dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_matmul_flops_and_bytes_pinned():
    c = analyze(_MATMUL)
    # 2 * M*N * K
    assert c.flops == 2 * (8 * 32) * 64
    # parameters are free; the dot reads both operands and writes its result
    assert c.bytes == 4 * (8 * 32 + 8 * 64 + 64 * 32)
    assert c.wire_bytes == 0
    assert c.unknown_loops == 0


_CONV = """\
HloModule m

ENTRY %main (p0: f32[1,16,16,8], p1: f32[3,3,8,16]) -> f32[1,16,16,16] {
  %p0 = f32[1,16,16,8] parameter(0)
  %p1 = f32[3,3,8,16] parameter(1)
  ROOT %conv.1 = f32[1,16,16,16] convolution(%p0, %p1), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f
}
"""


def test_conv_flops_pinned():
    c = analyze(_CONV)
    # 2 * out_elems * (kernel elems per output) = 2 * (16*16*16) * (3*3*8)
    assert c.flops == 2 * (16 * 16 * 16) * (3 * 3 * 8)
    assert c.bytes == 4 * (16 * 16 * 16 + 16 * 16 * 8 + 3 * 3 * 8 * 16)


_PSUM = """\
HloModule m

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024] parameter(0)
  ROOT %ar.1 = f32[1024] all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""


def test_psum_bytes_counts_and_ring_wire_pinned():
    c = analyze(_PSUM)
    buf = 1024 * 4
    assert c.flops == 0
    assert c.coll_counts["all-reduce"] == 1
    assert c.coll_bytes["all-reduce"] == buf
    # hbm: read + write the buffer; wire: bidirectional ring factor
    assert c.bytes == 2 * buf
    assert c.wire_bytes == 2 * buf * (4 - 1) / 4


_SCAN = """\
HloModule m

%body (p: f32[128]) -> f32[128] {
  %p = f32[128] parameter(0)
  %ar.2 = f32[128] all-reduce(%p), replica_groups={{0,1}}, to_apply=%add
  ROOT %add.1 = f32[128] add(%ar.2, %p)
}

%cond (p: f32[128]) -> pred[] {
  %p = f32[128] parameter(0)
  ROOT %lt.1 = pred[] compare(%p, %p), direction=LT
}

ENTRY %main (p0: f32[128]) -> f32[128] {
  %p0 = f32[128] parameter(0)
  ROOT %w.1 = f32[128] while(%p0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"16"}}
}
"""


def test_while_loop_multiplies_by_trip_count():
    c = analyze(_SCAN)
    buf = 128 * 4
    # body: one add (128 flops) per trip; cond: one compare (1 flop)
    assert c.flops == 16 * (128 + 1)
    # the in-loop collective is counted per trip, not once
    assert c.coll_counts["all-reduce"] == 16
    assert c.coll_bytes["all-reduce"] == 16 * buf
    assert c.wire_bytes == 16 * 2 * buf * (2 - 1) / 2
    assert c.unknown_loops == 0


def test_unannotated_while_counts_once_and_reports():
    txt = _SCAN.replace(
        ', backend_config={"known_trip_count":{"n":"16"}}', "")
    c = analyze(txt)
    assert c.unknown_loops == 1
    assert c.flops == 128 + 1
    assert c.coll_counts["all-reduce"] == 1
