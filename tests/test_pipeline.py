"""Pipeline parallelism: the fill-drain schedule equals sequential stage
application, and the pipelined decode path is a bit-exact drop-in for
``decode_step`` on per-example-independent (dense float) models. The
multi-device cases run on a real 4-device CPU mesh in a subprocess (the
main test process stays single-device)."""
import json
import os
import subprocess
import sys

import pytest


def _run(script: str) -> dict:
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, json
from repro.distributed.pipeline import pipeline_forward, split_stages
from repro.models.lm.config import ModelConfig
from repro.models.lm.model import init, layer_plan, apply_block

cfg = ModelConfig(n_layers=8, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                  vocab=61, remat="none", dtype="float32")
params = init(cfg, jax.random.PRNGKey(0))
unit, reps, rest = layer_plan(cfg)
assert reps == 8 and not rest

mesh = jax.make_mesh((4,), ("stage",))
res = {}
for M in (6, 2):       # M=2 < S=4: the pipe never fully fills
    mb, S, D = 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, S, D)) * 0.3
    q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (mb, S))

    def stage_fn(p_slice, xb):
        def unit_fn(xc, p_list):
            for j, kind in enumerate(unit):
                xc, _, _ = apply_block(kind, p_list[j], cfg, xc, q_pos)
            return xc, None
        xb, _ = jax.lax.scan(unit_fn, xb, p_slice)
        return xb

    def ref_apply(xb):
        return stage_fn(jax.tree.map(lambda l: l, params["scan"]), xb)

    ref = jax.vmap(ref_apply)(x)
    stage_params = split_stages(params["scan"], 4)
    got = pipeline_forward(stage_params, x, stage_fn, mesh)
    res[f"rel_err_M{M}"] = float(
        jnp.abs(got - ref).max() / (jnp.abs(ref).max() + 1e-9))
print(json.dumps(res))
"""


def test_pipeline_matches_sequential():
    res = _run(_SUBPROC)
    assert res["rel_err_M6"] < 1e-5, res
    assert res["rel_err_M2"] < 1e-5, res   # M < S: fill-drain only


def test_split_stages_non_divisible_raises():
    import jax.numpy as jnp

    from repro.distributed.pipeline import split_stages

    with pytest.raises(ValueError, match="do not factor"):
        split_stages({"w": jnp.zeros((8, 3))}, 3)


_DECODE_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.distributed.pipeline import pipeline_decode_step
from repro.models.lm import model as M
from repro.models.lm.config import ModelConfig

cfg = ModelConfig(n_layers=8, d_model=128, n_heads=4, n_kv_heads=4,
                  head_dim=32, d_ff=256, vocab=512, dtype="float32",
                  remat="none")
key = jax.random.PRNGKey(0)
params = M.init(cfg, key)
B, L = 8, 32
toks = jax.random.randint(jax.random.fold_in(key, 1), (B, 1), 0, cfg.vocab,
                          jnp.int32)
lg0, st0 = jax.jit(M.decode_step, static_argnums=1)(
    params, cfg, toks, M.init_state(cfg, B, L))
mesh = Mesh(np.asarray(jax.devices()[:4]), ("stage",))
res = {}
# bit-parity at M == S and M < S (fewer microbatches than stages)
for n_micro in (4, 2):
    lg1, st1 = pipeline_decode_step(params, cfg, toks, M.init_state(cfg, B, L),
                                    mesh=mesh, n_stages=4,
                                    n_microbatch=n_micro)
    eq = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), st0, st1)
    res[f"logits_bitwise_m{n_micro}"] = bool(jnp.array_equal(lg0, lg1))
    res[f"state_bitwise_m{n_micro}"] = all(jax.tree.leaves(eq))
try:
    pipeline_decode_step(params, cfg, toks, M.init_state(cfg, B, L),
                         mesh=mesh, n_stages=3)
    res["raises"] = False
except ValueError as e:
    res["raises"] = "do not factor" in str(e)
print(json.dumps(res))
"""


def test_pipeline_decode_bit_parity():
    """Pipelined decode == sequential decode bitwise on a dense float
    model (microbatching only slices the batch axis), including the
    M < S fill-drain-only schedule; non-factoring depth raises."""
    res = _run(_DECODE_SUBPROC)
    assert res["logits_bitwise_m4"] and res["state_bitwise_m4"], res
    assert res["logits_bitwise_m2"] and res["state_bitwise_m2"], res
    assert res["raises"] is True, res


_ENGINE_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import json
import numpy as np
import jax
from repro.analysis import hlo
from repro.analysis.rules import run_rules
from repro.models.lm import model as M
from repro.models.lm.config import ModelConfig
from repro.serving import Request, SamplerConfig, ServeEngine

cfg = ModelConfig(n_layers=8, d_model=128, n_heads=4, n_kv_heads=4,
                  head_dim=32, d_ff=256, vocab=512, dtype="float32",
                  remat="none")
params = M.init(cfg, jax.random.PRNGKey(0))

def reqs():
    rng = np.random.default_rng(0)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=5)
                    .astype(np.int32), max_new_tokens=6) for i in range(4)]

res = {}
e0 = ServeEngine(cfg, params, max_batch=4, max_len=64,
                 sampler=SamplerConfig(temperature=0.0))
for r in reqs():
    e0.submit(r)
out0 = {c.rid: c.tokens for c in e0.run()}
e0.close()
e1 = ServeEngine(cfg, params, max_batch=4, max_len=64,
                 sampler=SamplerConfig(temperature=0.0),
                 pipeline_stages=4, pipeline_microbatches=2)
for r in reqs():
    e1.submit(r)
out1 = {c.rid: c.tokens for c in e1.run()}
res["token_parity"] = out0 == out1
hps = e1.hot_paths()
res["decode_family"] = [h.name for h in hps if "decode" in h.name]
res["violations"] = [f"{h.name}:{v.rule}:{v.msg[:80]}"
                     for h in hps for v in run_rules(h)]
dec = next(h for h in hps if "decode" in h.name)
counts = [hlo.collective_counts(p.compiled_text()) for p in dec.programs]
res["permutes"] = counts[0].get("collective-permute", 0)
res["permute_cap"] = dict(dec.budget.collectives).get("collective-permute")
res["flat"] = all(c == counts[0] for c in counts)
try:
    ServeEngine(cfg, params, max_batch=4, max_len=64, pipeline_stages=3)
    res["bad_stage_raises"] = False
except ValueError:
    res["bad_stage_raises"] = True
e1.close()
print(json.dumps(res))
"""


def test_pipeline_engine_decode():
    """`pipeline_stages=N` serves the same tokens as the sequential
    engine, registers a `lm.decode.pipelined` family whose permute count
    stays in budget and flat across the drain family, and rejects depths
    that do not factor."""
    res = _run(_ENGINE_SUBPROC)
    assert res["token_parity"], res
    assert res["decode_family"] == ["lm.decode.pipelined"], res
    assert res["violations"] == [], res["violations"]
    assert res["permute_cap"] is not None
    assert 0 < res["permutes"] <= res["permute_cap"], res
    assert res["flat"], res
    assert res["bad_stage_raises"], res
