"""Pipeline parallelism: the fill-drain schedule equals sequential stage
application. Runs on a real 4-device CPU mesh in a subprocess (the main
test process stays single-device)."""
import json
import os
import subprocess
import sys

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, json
from repro.distributed.pipeline import pipeline_forward, split_stages
from repro.models.lm.config import ModelConfig
from repro.models.lm.model import init, layer_plan, apply_block

cfg = ModelConfig(n_layers=8, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                  vocab=61, remat="none", dtype="float32")
params = init(cfg, jax.random.PRNGKey(0))
unit, reps, rest = layer_plan(cfg)
assert reps == 8 and not rest

mesh = jax.make_mesh((4,), ("stage",))
M, mb, S, D = 6, 2, 16, cfg.d_model
x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, S, D)) * 0.3
q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (mb, S))

def stage_fn(p_slice, xb):
    def unit_fn(xc, p_list):
        for j, kind in enumerate(unit):
            xc, _, _ = apply_block(kind, p_list[j], cfg, xc, q_pos)
        return xc, None
    xb, _ = jax.lax.scan(unit_fn, xb, p_slice)
    return xb

# reference: all reps sequentially on each microbatch
def ref_apply(xb):
    return stage_fn(jax.tree.map(lambda l: l, params["scan"]), xb)

ref = jax.vmap(ref_apply)(x)

stage_params = split_stages(params["scan"], 4)
got = pipeline_forward(stage_params, x, stage_fn, mesh)
err = float(jnp.abs(got - ref).max() / (jnp.abs(ref).max() + 1e-9))
print(json.dumps({"rel_err": err}))
"""


def test_pipeline_matches_sequential():
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["rel_err"] < 1e-5, res
