"""The cached/fused inference fast path (DESIGN.md §3).

Covers the acceptance criteria of the prepack/fusion PR:
  * PackedWeight round-trips bit-exactly vs the int-direct oracle on every
    backend, including the single-launch fused Pallas kernel;
  * the fused implicit-im2col conv agrees with lax.conv_general_dilated
    (within quantization error) and with the materialized im2col path
    bit-exactly across stride/padding;
  * the fused conv never materializes the (N*OH*OW, KH*KW*C) patch matrix
    (jaxpr inspection);
  * repeated serving calls neither recompile nor re-quantize/re-pack the
    weights.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PackedConvWeight,
    PackedWeight,
    PIMQuantConfig,
    fuse_conv_heuristic,
    pim_conv2d,
    pim_linear,
    prepack_conv2d,
    prepack_linear,
)
from repro.core.bitserial import int_matmul_direct, int_matmul_prepacked

ALL_BACKENDS = ("int-direct", "mxu-plane", "popcount", "pallas")


# ---------------------------------------------------------------------------
# PackedWeight matmul fast path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("ab,wb", [(8, 8), (4, 2)])
def test_packed_weight_bit_exact_vs_int_direct(backend, ab, wb):
    """P through the prepacked planes == the oracle on the same codes."""
    w = jax.random.normal(jax.random.PRNGKey(0), (96, 40))
    pk = prepack_linear(w, PIMQuantConfig(w_bits=wb, a_bits=ab))
    qa = jax.random.randint(jax.random.PRNGKey(1), (6, 96), 0, 2**ab)
    got = int_matmul_prepacked(qa, pk, ab, backend)
    want = int_matmul_direct(qa, pk.codes)
    assert got.dtype == jnp.int32
    assert (got == want).all()


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_packed_weight_matches_per_call_quantized_matmul(backend):
    """Deployment path (prepack once) == seed path (quantize every call)."""
    a = jax.random.normal(jax.random.PRNGKey(2), (5, 160))
    w = jax.random.normal(jax.random.PRNGKey(3), (160, 24))
    cfg = PIMQuantConfig(w_bits=8, a_bits=8, backend=backend)
    pk = prepack_linear(w, cfg)
    y_cached = pim_linear(a, pk, cfg=cfg)
    y_percall = pim_linear(a, w, cfg=cfg)
    assert jnp.array_equal(y_cached, y_percall)


def test_packed_weight_col_sums_and_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(4), (70, 12))
    pk = prepack_linear(w, PIMQuantConfig(w_bits=8, a_bits=8))
    assert (pk.col_sums == pk.codes.sum(0)).all()
    # dequantized master within one quantization step of the original
    assert float(jnp.abs(pk.to_float() - w).max()) <= float(pk.wq.scale)


def test_packed_weight_is_a_pytree():
    """PackedWeight jits, vmaps and scans like any parameter leaf."""
    w = jax.random.normal(jax.random.PRNGKey(5), (3, 64, 16))  # stacked reps
    from functools import partial

    from repro.core.packed import prepack

    pk = jax.vmap(partial(prepack, w_bits=8))(w)
    assert pk.codes.shape == (3, 64, 16)
    for r in range(3):
        ref = prepack(w[r], 8)
        sl = jax.tree.map(lambda l: l[r], pk)
        assert (sl.codes == ref.codes).all()
        assert (sl.planes == ref.planes).all()


# ---------------------------------------------------------------------------
# Fused implicit-im2col conv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride,padding", [(1, 0), (1, 1), (2, 1), (2, 0),
                                            (1, 2)])
def test_fused_conv_matches_materialized_bit_exact(stride, padding):
    """Same codes through both lowerings -> identical outputs, any geometry."""
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 9, 9, 33))  # odd C: pad
    w = jax.random.normal(jax.random.PRNGKey(7), (3, 3, 33, 16)) * 0.2
    cfg = PIMQuantConfig(w_bits=8, a_bits=8, backend="pallas")
    pk = prepack_conv2d(w, cfg)
    y_fused = pim_conv2d(x, pk, stride=stride, padding=padding, cfg=cfg,
                         conv_mode="fused")
    cfg_i = PIMQuantConfig(w_bits=8, a_bits=8, backend="int-direct")
    y_mat = pim_conv2d(x, pk, stride=stride, padding=padding, cfg=cfg_i,
                       conv_mode="im2col")
    assert jnp.array_equal(y_fused, y_mat)


@pytest.mark.parametrize("stride,padding", [(1, 1), (2, 1)])
def test_fused_conv_tracks_lax_conv(stride, padding):
    """8-bit fused conv stays within quantization error of the float conv."""
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 10, 10, 32))
    w = jax.random.normal(jax.random.PRNGKey(9), (3, 3, 32, 16)) * 0.1
    cfg = PIMQuantConfig(w_bits=8, a_bits=8, backend="pallas")
    y = pim_conv2d(x, prepack_conv2d(w, cfg), stride=stride, padding=padding,
                   cfg=cfg, conv_mode="fused")
    ref = jax.lax.conv_general_dilated(
        x, w, (stride, stride), [(padding, padding)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    assert y.shape == ref.shape
    scale = float(jnp.abs(ref).max())
    assert float(jnp.abs(y - ref).max()) <= 0.05 * scale + 1e-3


def _jaxpr_avals(jaxpr):
    """All intermediate avals, recursing into sub-jaxprs (pjit/scan/pallas)."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                yield v.aval
        for val in eqn.params.values():
            inner = getattr(val, "jaxpr", None)
            if inner is not None:
                yield from _jaxpr_avals(inner)


def test_fused_conv_never_materializes_patch_matrix():
    """No intermediate anywhere in the jaxpr is as large as the im2col
    matrix — the defining property of the implicit-im2col kernel."""
    n, h, c, o, kk, pad = 2, 16, 32, 16, 3, 1
    x = jax.random.normal(jax.random.PRNGKey(10), (n, h, h, c))
    w = jax.random.normal(jax.random.PRNGKey(11), (kk, kk, c, o)) * 0.1
    cfg = PIMQuantConfig(w_bits=8, a_bits=8, backend="pallas")
    pk = prepack_conv2d(w, cfg)
    oh = h + 2 * pad - kk + 1
    im2col_elems = n * oh * oh * kk * kk * c

    fused = jax.make_jaxpr(lambda xx: pim_conv2d(
        xx, pk, stride=1, padding=pad, cfg=cfg, conv_mode="fused"))(x)
    big = [a for a in _jaxpr_avals(fused.jaxpr)
           if int(np.prod(a.shape)) >= im2col_elems]
    assert not big, f"fused path materialized {[a.shape for a in big]}"

    # positive control: the materialized path DOES build the patch matrix
    cfg_i = PIMQuantConfig(w_bits=8, a_bits=8, backend="int-direct")
    mat = jax.make_jaxpr(lambda xx: pim_conv2d(
        xx, pk, stride=1, padding=pad, cfg=cfg_i, conv_mode="im2col"))(x)
    assert any(int(np.prod(a.shape)) >= im2col_elems
               for a in _jaxpr_avals(mat.jaxpr))


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("stride,padding", [(2, 1), (2, 2), (1, 2)])
def test_fused_conv_stride2_nonsquare_odd_width(bits, stride, padding):
    """Fused == materialized bit-exactly on non-square, odd-width inputs
    with stride 2 and padding > 0 (the fastpath suite above only walked
    stride-1 geometries), across the paper's <2:2>/<4:4>/<8:8> sweep."""
    x = jax.random.normal(jax.random.PRNGKey(20), (2, 9, 13, 5))
    w = jax.random.normal(jax.random.PRNGKey(21), (3, 3, 5, 8)) * 0.2
    cfg_f = PIMQuantConfig(w_bits=bits, a_bits=bits, backend="pallas")
    pk = prepack_conv2d(w, cfg_f)
    y_fused = pim_conv2d(x, pk, stride=stride, padding=padding, cfg=cfg_f,
                         conv_mode="fused")
    cfg_i = PIMQuantConfig(w_bits=bits, a_bits=bits, backend="int-direct")
    y_mat = pim_conv2d(x, pk, stride=stride, padding=padding, cfg=cfg_i,
                       conv_mode="im2col")
    assert y_fused.shape == y_mat.shape
    assert jnp.array_equal(y_fused, y_mat)


def test_fused_conv_odd_o_pads_not_degenerates():
    """Regression: prime O used to shrink the output block to bo=1 (an
    O-sized grid of tiny kernels). Now O pads up to the requested block and
    the result is sliced — same bits, bounded grid."""
    from repro.kernels.conv2d_fused import _pad_o_blocks

    # prime O with the default block: one padded 128-block step, not 131.
    assert _pad_o_blocks(131, 128) == (128, 125)
    assert _pad_o_blocks(67, 32) == (32, 29)     # grid 3, not 67
    assert _pad_o_blocks(65, 128) == (65, 0)     # O < block: single tile
    assert _pad_o_blocks(128, 128) == (128, 0)   # exact fit: no padding
    for o, bo in [(131, 128), (67, 32), (193, 128)]:
        b, pad = _pad_o_blocks(o, bo)
        assert (o + pad) % b == 0
        assert (o + pad) // b <= -(-o // b)      # never more tiles than ceil

    x = jax.random.normal(jax.random.PRNGKey(22), (1, 6, 6, 8))
    w = jax.random.normal(jax.random.PRNGKey(23), (3, 3, 8, 131)) * 0.2
    cfg_f = PIMQuantConfig(w_bits=4, a_bits=4, backend="pallas")
    pk = prepack_conv2d(w, cfg_f)
    y_fused = pim_conv2d(x, pk, stride=1, padding=1, cfg=cfg_f,
                         conv_mode="fused")
    cfg_i = PIMQuantConfig(w_bits=4, a_bits=4, backend="int-direct")
    y_mat = pim_conv2d(x, pk, stride=1, padding=1, cfg=cfg_i,
                       conv_mode="im2col")
    assert y_fused.shape == (1, 6, 6, 131)
    assert jnp.array_equal(y_fused, y_mat)


def test_conv_activation_calibration_ignores_padding():
    """Regression: activation quantization used to calibrate on the padded
    tensor, so a strictly-positive input range (post-ReLU features) was
    stretched down to the padding zeros — wasted code space, inflated
    error. Calibrating on the real input must beat the old behavior."""
    key = jax.random.PRNGKey(24)
    # post-ReLU-like features in [2, 5]: zero is far outside the range
    x = jax.random.uniform(key, (2, 8, 8, 16), minval=2.0, maxval=5.0)
    w = jax.random.normal(jax.random.PRNGKey(25), (3, 3, 16, 8)) * 0.1
    cfg = PIMQuantConfig(w_bits=4, a_bits=4, backend="int-direct")
    ref = jax.lax.conv_general_dilated(
        x, w, (1, 1), [(1, 1)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y_new = pim_conv2d(x, w, stride=1, padding=1, cfg=cfg)
    # Old behavior, reconstructed: pre-pad the input so calibration sees the
    # zeros (exactly what calibrate_minmax(xp) did before the fix).
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    y_old = pim_conv2d(xp, w, stride=1, padding=0, cfg=cfg)
    assert y_new.shape == y_old.shape == ref.shape
    err_new = float(jnp.abs(y_new - ref).max())
    err_old = float(jnp.abs(y_old - ref).max())
    assert err_new < err_old, (err_new, err_old)


def test_unquantized_conv_bias_preserves_dtype():
    """Regression: the cfg=None fallback added a float32 bias without a
    cast, silently upcasting a bf16 model's activations on that path only."""
    x = jax.random.normal(jax.random.PRNGKey(26), (2, 8, 8, 4)).astype(
        jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(27), (3, 3, 4, 8))
    b = jnp.ones((8,), jnp.float32)
    y = pim_conv2d(x, w, b, stride=1, padding=1, cfg=None)
    assert y.dtype == jnp.bfloat16
    # packed weights take the same fallback when cfg is disabled
    pk = prepack_conv2d(w, PIMQuantConfig(w_bits=8, a_bits=8))
    y2 = pim_conv2d(x, pk, b, stride=1, padding=1, cfg=None)
    assert y2.dtype == jnp.bfloat16


def test_fuse_heuristic_dispatch():
    """auto mode: big maps fuse on the pallas backend, 1x1 and XLA don't."""
    assert fuse_conv_heuristic(64, 112, 112, 3, 3, 64, "pallas")
    assert not fuse_conv_heuristic(64, 112, 112, 1, 1, 64, "pallas")
    assert not fuse_conv_heuristic(64, 112, 112, 3, 3, 64, "int-direct")
    assert not fuse_conv_heuristic(1, 4, 4, 3, 3, 8, "pallas")  # tiny map


# ---------------------------------------------------------------------------
# Serving: quantize+pack exactly once, no recompilation
# ---------------------------------------------------------------------------

def test_no_repack_no_recompile_on_repeated_calls(monkeypatch):
    """After prepack, repeated jitted calls never re-calibrate the weight
    and never re-trace: the paper's program-subarrays-once property."""
    from repro.core import bitserial as bs

    w = jax.random.normal(jax.random.PRNGKey(12), (128, 64))
    cfg = PIMQuantConfig(w_bits=8, a_bits=8, backend="popcount")
    pk = prepack_linear(w, cfg)

    seen = []
    orig = bs.calibrate_minmax
    monkeypatch.setattr(bs, "calibrate_minmax",
                        lambda x, bits, **kw: (seen.append(x.shape),
                                               orig(x, bits, **kw))[1])
    step = jax.jit(lambda x: pim_linear(x, pk, cfg=cfg))
    for i in range(4):
        step(jax.random.normal(jax.random.PRNGKey(i), (8, 128))).block_until_ready()
    # Traced once (one activation-side calibration), zero weight-side ones.
    assert step._cache_size() == 1
    assert seen == [(8, 128)]


def test_engine_prepacks_weights_once():
    """ServeEngine with a pim config serves from PackedWeight params and
    matches the whole-sequence prepacked forward greedily."""
    from repro.models.lm import ModelConfig, forward, init, prepack_params
    from repro.serving import Request, SamplerConfig, ServeEngine

    pim = PIMQuantConfig(w_bits=8, a_bits=8, backend="int-direct")
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                      d_ff=64, vocab=41, remat="none", dtype="float32",
                      pim=pim)
    params = init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32,
                      sampler=SamplerConfig(temperature=0.0))
    # the engine's param tree holds PackedWeight leaves, not float masters
    leaves = jax.tree.leaves(eng.params, is_leaf=lambda l: isinstance(l, PackedWeight))
    assert any(isinstance(l, PackedWeight) for l in leaves)

    pk = prepack_params(params, pim)
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    toks = list(prompt)
    for _ in range(5):
        lg, _ = forward(pk, cfg, jnp.asarray([toks], jnp.int32))
        toks.append(int(jnp.argmax(lg[0, -1])))
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    done = eng.run()
    assert done[0].tokens == toks[len(prompt):]
    # repeated decode steps reuse the compiled drain programs: one cache
    # entry per power-of-two scan length, each compiled exactly once
    assert all(fn._cache_size() == 1 for fn in eng._decode.values())


def test_prepack_packs_moe_expert_banks():
    """MoE expert banks ride the prepacked fast path: (E, d, f) leaves in
    router-bearing dicts pack per expert (one vmap level deeper than the
    scan stack), the router stays float, and forward runs the packed
    bit-serial expert FFN end to end — both for scan-stacked (R, E, d, f)
    banks and for raw (E, d, f) banks in remainder layers."""
    from repro.models.lm import ModelConfig, MoEConfig, forward, init, prepack_params

    pim = PIMQuantConfig(w_bits=8, a_bits=8, backend="int-direct")
    # 8x attn + rglru: the scan unit caps at 8 blocks, so the 9th layer
    # lands in "rest" with its raw (E, d, f) MoE expert bank.
    cfg = ModelConfig(n_layers=9, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab=31, remat="none", dtype="float32",
                      family="moe", moe=MoEConfig(n_experts=4, top_k=2),
                      block_pattern=("attn",) * 8 + ("rglru",),
                      pim=pim)
    params = init(cfg, jax.random.PRNGKey(0))
    pk = prepack_params(params, pim)
    rest_ffn = pk["rest"][0]["ffn"]
    e, d, f = params["rest"][0]["ffn"]["w_in"].shape
    assert isinstance(rest_ffn["w_in"], PackedWeight)
    assert rest_ffn["w_in"].codes.shape == (e, d, f)         # expert-stacked
    assert rest_ffn["w_in"].col_sums.shape == (e, f)
    assert not isinstance(rest_ffn["router"], PackedWeight)  # router float
    scan_ffn = pk["scan"][0]["ffn"]
    assert isinstance(scan_ffn["w_in"], PackedWeight)
    assert scan_ffn["w_in"].codes.shape == (8, e, d, f)      # scan + experts
    assert isinstance(pk["rest"][0]["rglru"]["w_x"], PackedWeight)
    x = jnp.asarray([[3, 1, 4, 1, 5]], jnp.int32)
    logits, _ = forward(pk, cfg, x)
    assert jnp.isfinite(logits).all()
    # Prepacked at deploy time == packed per call from the same masters:
    # prepack is deterministic, so the fast path's codes are exactly the
    # ones a fresh pack of the float masters would produce.
    logits2, _ = forward(prepack_params(params, pim), cfg, x)
    assert jnp.array_equal(logits, logits2)


def test_cnn_prepack_bit_exact_and_conv_weights_packed():
    from repro.models.cnn import alexnet

    params = alexnet.init(jax.random.PRNGKey(0), image=64, num_classes=10)
    cfg = PIMQuantConfig(w_bits=8, a_bits=8, backend="int-direct")
    pk = alexnet.prepack(params, cfg)
    assert isinstance(pk["conv1"]["w"], PackedConvWeight)
    assert isinstance(pk["fc1"]["w"], PackedWeight)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64, 3))
    assert jnp.array_equal(alexnet.apply(params, x, cfg=cfg),
                           alexnet.apply(pk, x, cfg=cfg))
