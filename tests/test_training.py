"""Training substrate: optimizer correctness, accumulation equivalence,
checkpoint roundtrip/atomicity, fault-tolerant loop, grad compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import ModelConfig, init
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, SyntheticLM
from repro.training.fault_tolerance import (
    FTConfig, RestartPolicy, StragglerDetector, run_resilient,
)
from repro.training.optimizer import OptimizerConfig, apply_updates, init_opt_state
from repro.training.train_loop import make_train_step

CFG = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                  vocab=61, remat="none", dtype="float32")


@pytest.fixture(scope="module")
def setup():
    params = init(CFG, jax.random.PRNGKey(0))
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=100,
                           keep_master=False)
    opt = init_opt_state(ocfg, params)
    data = SyntheticLM(DataConfig(vocab=CFG.vocab, seq_len=16, global_batch=4))
    return params, ocfg, opt, data


def test_loss_decreases(setup):
    params, ocfg, opt, data = setup
    step = jax.jit(make_train_step(CFG, ocfg))
    losses = []
    for i in range(20):
        b = jax.tree.map(jnp.asarray, data.batch(i))
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


def test_grad_accum_matches_full_batch(setup):
    params, ocfg, opt, data = setup
    b = jax.tree.map(jnp.asarray, data.batch(0))
    s1 = jax.jit(make_train_step(CFG, ocfg, accum=1))
    s4 = jax.jit(make_train_step(CFG, ocfg, accum=4))
    p1, o1, m1 = s1(params, opt, b)
    p4, o4, m4 = s4(params, opt, b)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    d = max(float(jnp.abs(a - c).max())
            for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 1e-5, f"accum changed the update by {d}"


def test_adamw_decay_mask():
    p = {"w_in": jnp.ones((4, 4)), "norm": {"scale": jnp.ones((4,))}}
    g = jax.tree.map(jnp.zeros_like, p)
    ocfg = OptimizerConfig(lr=1.0, warmup_steps=0, total_steps=1,
                           weight_decay=0.5, keep_master=False)
    st = init_opt_state(ocfg, p)
    newp, _, _ = apply_updates(ocfg, p, g, st)
    assert float(newp["w_in"][0, 0]) < 1.0          # decayed
    assert float(newp["norm"]["scale"][0]) == 1.0   # masked


def test_checkpoint_roundtrip(tmp_path, setup):
    params, ocfg, opt, _ = setup
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, (params, opt))
    assert ckpt.latest_step(d) == 7
    (p2, o2), manifest = ckpt.restore(d, (params, opt))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 7


def test_checkpoint_atomic_pointer(tmp_path, setup):
    params, _, opt, _ = setup
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, (params,))
    ckpt.save(d, 2, (params,))
    assert ckpt.latest_step(d) == 2
    # a stale tmp dir must never be visible as a checkpoint
    assert not any(x.startswith(".tmp") for x in os.listdir(d)
                   if os.path.isdir(os.path.join(d, x)))


def test_checkpoint_async(tmp_path, setup):
    params, _, opt, _ = setup
    d = str(tmp_path / "ck")
    t = ckpt.save_async(d, 3, (params,))
    t.join()
    assert ckpt.latest_step(d) == 3


def test_resilient_loop_recovers_from_injected_failures(tmp_path, setup):
    params, ocfg, opt, data = setup
    step = jax.jit(make_train_step(CFG, ocfg))
    ft = FTConfig(ckpt_dir=str(tmp_path / "ft"), ckpt_every=5, max_failures=5)
    boom = {"left": 2}

    def injector(s):
        if s == 12 and boom["left"] > 0:
            boom["left"] -= 1
            raise RuntimeError("injected node failure")

    p, o, stats = run_resilient(
        step, params, opt, data, 20, ft,
        put_batch=lambda b: jax.tree.map(jnp.asarray, b),
        fail_injector=injector)
    assert stats["restarts"] == 2
    assert ckpt.latest_step(ft.ckpt_dir) == 19


def test_resilient_restart_is_deterministic(tmp_path, setup):
    """A run preempted at step K and resumed equals an uninterrupted run."""
    params, ocfg, opt, data = setup
    step = jax.jit(make_train_step(CFG, ocfg))

    def run(ckdir, inject):
        ft = FTConfig(ckpt_dir=ckdir, ckpt_every=4, max_failures=3)
        boom = {"armed": inject}

        def injector(s):
            if s == 9 and boom["armed"]:
                boom["armed"] = False
                raise RuntimeError("preempt")

        return run_resilient(step, params, opt, data, 14, ft,
                             put_batch=lambda b: jax.tree.map(jnp.asarray, b),
                             fail_injector=injector)

    p_a, _, _ = run(str(tmp_path / "a"), inject=False)
    p_b, _, stats_b = run(str(tmp_path / "b"), inject=True)
    assert stats_b["restarts"] == 1
    for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_straggler_detector():
    det = StragglerDetector(z_thresh=4.0)
    for _ in range(32):
        det.observe(0.10)
    assert det.observe(0.101) is False
    assert det.observe(5.0) is True
    assert det.flagged == 1


def test_straggler_detector_matches_sorted_reference():
    """The O(log n) deque + order-maintained-mirror detector must flag
    exactly what the straightforward full-sort-per-step implementation
    flags, across evictions, duplicates and heavy-tailed jitter."""

    class Reference:
        def __init__(self, z=4.0, window=128):
            self.z, self.window, self.times, self.flagged = z, window, [], 0

        def observe(self, dt):
            is_straggler = False
            if len(self.times) >= 16:
                s = sorted(self.times)
                med = s[len(s) // 2]
                mad = sorted(abs(t - med) for t in s)[len(s) // 2]
                sigma = max(1.4826 * mad, 0.05 * med, 1e-9)
                is_straggler = (dt - med) / sigma > self.z
                if is_straggler:
                    self.flagged += 1
            self.times.append(dt)
            if len(self.times) > self.window:
                self.times.pop(0)
            return is_straggler

    rng = np.random.default_rng(0)
    for trial in range(5):
        det, ref = StragglerDetector(z_thresh=4.0), Reference(z=4.0)
        for i in range(500):
            dt = float(rng.choice([0.1, 0.1, 0.1, 0.1001, 0.2,
                                   rng.lognormal(-2.0, 1.5)]))
            assert det.observe(dt) == ref.observe(dt), (trial, i, dt)
        assert det.flagged == ref.flagged


def test_restart_policy_budget():
    pol = RestartPolicy(max_failures=2, backoff_s=0.01)
    assert pol.on_failure() == 0.01
    assert pol.on_failure() == 0.02
    with pytest.raises(RuntimeError):
        pol.on_failure()


def test_grad_compression_preserves_training(setup):
    """Compressed-gradient training still reduces loss (error feedback)."""
    from repro.distributed.collectives import (
        CompressionConfig, init_error_feedback, make_grad_compressor)

    params, ocfg, opt, data = setup
    comp = make_grad_compressor(CompressionConfig(enabled=True, bits=8))
    err = init_error_feedback(params)

    def compress(grads, _err=err):
        g, _ = comp(grads, _err)
        return g

    step = jax.jit(make_train_step(CFG, ocfg, compress_grads=compress))
    losses = []
    for i in range(15):
        b = jax.tree.map(jnp.asarray, data.batch(i))
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05


def test_pim_qat_train_step_bf16():
    """--pim QAT path: STE fake-quant must not promote bf16 scan carries."""
    import dataclasses

    from repro.core.pim_layers import PIMQuantConfig

    cfg_pim = dataclasses.replace(
        CFG, dtype="bfloat16", pim=PIMQuantConfig(w_bits=8, a_bits=8))
    from repro.models.lm import init as minit
    from repro.models.lm.model import cast_params

    params = cast_params(minit(cfg_pim, jax.random.PRNGKey(0)), jnp.bfloat16)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(ocfg, params)
    data = SyntheticLM(DataConfig(vocab=CFG.vocab, seq_len=16, global_batch=2))
    step = jax.jit(make_train_step(cfg_pim, ocfg))
    b = jax.tree.map(jnp.asarray, data.batch(0))
    params, opt, m = step(params, opt, b)
    assert jnp.isfinite(m["loss"])
