"""Eq. 1 / Eq. 2 correctness: all bit-serial backends agree exactly with the
integer-matmul oracle, and the float-facing quantized matmul is within
quantization-error bounds of the dense product.

Hypothesis-based property tests live in tests/test_properties.py (optional
dependency); everything here runs on the bare container."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    calibrate_minmax, dequantize, quantize, quantized_matmul,
)
from repro.core.bitserial import (
    int_matmul_direct, int_matmul_mxu_plane, int_matmul_popcount,
)


def _codes(key, shape, bits):
    return jax.random.randint(key, shape, 0, 2**bits)


@pytest.mark.parametrize("backend", [int_matmul_popcount, int_matmul_mxu_plane])
@pytest.mark.parametrize("m,k,n,ab,wb", [
    (4, 32, 8, 1, 1), (8, 64, 16, 4, 4), (5, 100, 7, 8, 8),
    (16, 256, 32, 8, 2), (3, 33, 5, 2, 8),
])
def test_backends_match_integer_oracle(backend, m, k, n, ab, wb):
    qa = _codes(jax.random.PRNGKey(0), (m, k), ab)
    qw = _codes(jax.random.PRNGKey(1), (k, n), wb)
    got = backend(qa, qw, ab, wb)
    want = int_matmul_direct(qa, qw)
    assert (got == want).all()


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quantized_matmul_error_bound(bits):
    key = jax.random.PRNGKey(2)
    a = jax.random.normal(key, (6, 128)) * 2.0
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 10))
    y = quantized_matmul(a, w, a_bits=bits, w_bits=bits, backend="popcount")
    ref = a @ w
    # worst-case quant error per element ~ (|a| sa + |w| sw + sa sw) summed
    sa = (a.max() - a.min()) / (2**bits - 1)
    sw = (w.max() - w.min()) / (2**bits - 1)
    bound = 128 * (jnp.abs(a).max() * sw + jnp.abs(w).max() * sa + sa * sw)
    assert jnp.abs(y - ref).max() <= bound


@pytest.mark.parametrize("bits,lo,span", [
    (1, -100.0, 0.01), (4, -3.0, 6.0), (8, 50.0, 200.0), (8, -0.5, 1.0),
])
def test_quantize_roundtrip_bound(bits, lo, span):
    """|dequant(quant(x)) - x| <= scale/2 for x within the calibration range.

    Tolerance includes an f32-cancellation allowance proportional to the
    offset magnitude ((x - qmin) loses bits when span << |lo|). The
    hypothesis-randomized version lives in tests/test_properties.py."""
    x = jnp.linspace(lo, lo + span, 97)
    qp = calibrate_minmax(x, bits)
    err = jnp.abs(dequantize(quantize(x, qp), qp) - x)
    tol = float(qp.scale) / 2 + 1e-5 + 2e-5 * abs(lo)
    assert float(err.max()) <= tol


def test_prequantized_weights_path():
    """Legacy ``wq=``/``qw=`` kwargs of quantized_matmul still work (the
    prepack_weights helper that produced them is gone — PackedWeight via
    prepack_linear is the deployment path now)."""
    from repro.core.bitserial import quantized_matmul as qm

    a = jax.random.normal(jax.random.PRNGKey(4), (4, 64))
    w = jax.random.normal(jax.random.PRNGKey(5), (64, 12))
    wq = calibrate_minmax(w, 8)
    codes = quantize(w, wq)
    y1 = qm(a, w, 8, 8, backend="popcount")
    y2 = qm(a, w, 8, 8, backend="popcount", wq=wq, qw=codes)
    assert jnp.allclose(y1, y2)
