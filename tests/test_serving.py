"""Serving engine: greedy generation parity vs whole-sequence forward,
continuous-batching slot bookkeeping, snapshot determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import ModelConfig, forward, init
from repro.serving import Request, SamplerConfig, ServeEngine

CFG = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                  vocab=51, remat="none", dtype="float32")


@pytest.fixture(scope="module")
def setup():
    params = init(CFG, jax.random.PRNGKey(0))
    return params


def _greedy_reference(params, prompt, n_new):
    """Autoregressive greedy decode via repeated full forward (oracle)."""
    toks = list(prompt)
    for _ in range(n_new):
        logits, _ = forward(params, CFG, jnp.asarray([toks], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_naive_greedy(setup):
    params = setup
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    n_new = 6
    want = _greedy_reference(params, prompt.tolist(), n_new)
    eng = ServeEngine(CFG, params, max_batch=2, max_len=64,
                      sampler=SamplerConfig(temperature=0.0))
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=n_new))
    done = eng.run()
    assert len(done) == 1
    assert done[0].tokens == want, (done[0].tokens, want)


def test_continuous_batching_all_complete(setup):
    params = setup
    eng = ServeEngine(CFG, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    n_req = 5  # > max_batch forces slot recycling
    for rid in range(n_req):
        L = int(rng.integers(2, 9))
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, CFG.vocab, size=L).astype(np.int32), max_new_tokens=4))
    done = eng.run()
    assert sorted(c.rid for c in done) == list(range(n_req))
    assert all(len(c.tokens) == 4 for c in done)


def test_batched_slots_are_isolated(setup):
    """Two different prompts decoded together equal their solo decodes."""
    params = setup
    p1 = np.array([7, 8, 9], np.int32)
    p2 = np.array([10, 11, 12, 13], np.int32)

    def solo(prompt):
        e = ServeEngine(CFG, params, max_batch=2, max_len=64)
        e.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
        return e.run()[0].tokens

    w1, w2 = solo(p1), solo(p2)
    e = ServeEngine(CFG, params, max_batch=2, max_len=64)
    e.submit(Request(rid=1, prompt=p1, max_new_tokens=5))
    e.submit(Request(rid=2, prompt=p2, max_new_tokens=5))
    done = {c.rid: c.tokens for c in e.run()}
    assert done[1] == w1
    assert done[2] == w2
