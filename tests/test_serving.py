"""Serving engine: greedy generation parity vs whole-sequence forward,
continuous-batching slot bookkeeping, snapshot determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import ModelConfig, forward, init
from repro.serving import Request, SamplerConfig, ServeEngine

CFG = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                  vocab=51, remat="none", dtype="float32")


@pytest.fixture(scope="module")
def setup():
    params = init(CFG, jax.random.PRNGKey(0))
    return params


def _greedy_reference(params, prompt, n_new):
    """Autoregressive greedy decode via repeated full forward (oracle)."""
    toks = list(prompt)
    for _ in range(n_new):
        logits, _ = forward(params, CFG, jnp.asarray([toks], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_naive_greedy(setup):
    params = setup
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    n_new = 6
    want = _greedy_reference(params, prompt.tolist(), n_new)
    eng = ServeEngine(CFG, params, max_batch=2, max_len=64,
                      sampler=SamplerConfig(temperature=0.0))
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=n_new))
    done = eng.run()
    assert len(done) == 1
    assert done[0].tokens == want, (done[0].tokens, want)


def test_continuous_batching_all_complete(setup):
    params = setup
    eng = ServeEngine(CFG, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    n_req = 5  # > max_batch forces slot recycling
    for rid in range(n_req):
        L = int(rng.integers(2, 9))
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, CFG.vocab, size=L).astype(np.int32), max_new_tokens=4))
    done = eng.run()
    assert sorted(c.rid for c in done) == list(range(n_req))
    assert all(len(c.tokens) == 4 for c in done)


def test_batched_slots_are_isolated(setup):
    """Two different prompts decoded together equal their solo decodes."""
    params = setup
    p1 = np.array([7, 8, 9], np.int32)
    p2 = np.array([10, 11, 12, 13], np.int32)

    def solo(prompt):
        e = ServeEngine(CFG, params, max_batch=2, max_len=64)
        e.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
        return e.run()[0].tokens

    w1, w2 = solo(p1), solo(p2)
    e = ServeEngine(CFG, params, max_batch=2, max_len=64)
    e.submit(Request(rid=1, prompt=p1, max_new_tokens=5))
    e.submit(Request(rid=2, prompt=p2, max_new_tokens=5))
    done = {c.rid: c.tokens for c in e.run()}
    assert done[1] == w1
    assert done[2] == w2


def test_mixed_workload_token_identical(setup):
    """Continuous-batching correctness: staggered submits, different prompt
    lengths, EOS mid-stream, and slot reuse after release produce output
    token-identical to generating each request alone (forward oracle)."""
    params = setup
    prompts = {
        0: np.array([3, 1, 4, 1, 5], np.int32),
        1: np.array([7, 8], np.int32),
        2: np.array([9, 2, 6, 5, 3, 5, 8], np.int32),
        3: np.array([11, 12, 13], np.int32),
    }
    max_new = {0: 6, 1: 4, 2: 5, 3: 6}
    want = {rid: _greedy_reference(params, p.tolist(), max_new[rid])
            for rid, p in prompts.items()}
    # rid 2 terminates on EOS mid-stream: its eos id is a token the greedy
    # stream is known to emit; expectation truncates at first occurrence.
    eos = {rid: -1 for rid in prompts}
    eos[2] = want[2][2]
    j = want[2].index(eos[2])
    want[2] = want[2][:j + 1]

    eng = ServeEngine(CFG, params, max_batch=2, max_len=64,
                      sampler=SamplerConfig(temperature=0.0))
    eng.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=max_new[0]))
    eng.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=max_new[1]))
    done = []
    done += eng.step()   # both admitted, one token each
    done += eng.step()
    # staggered: 2 more requests arrive while the grid is mid-decode; they
    # reuse slots released by rid 0/1 (4 requests > 2 slots).
    eng.submit(Request(rid=2, prompt=prompts[2], max_new_tokens=max_new[2],
                       eos_id=int(eos[2])))
    eng.submit(Request(rid=3, prompt=prompts[3], max_new_tokens=max_new[3]))
    done += eng.run()
    got = {c.rid: c.tokens for c in done}
    assert got == want


def test_prefill_bucketing_bounds_compiles(setup):
    """Power-of-two chunked prefill: a varied-prompt-length workload compiles
    at most ceil(log2(max_len)) prefill variants, and the decode drain at
    most log2(drain_steps)+1 scan-length variants."""
    import math

    params = setup
    max_len = 64
    eng = ServeEngine(CFG, params, max_batch=2, max_len=max_len)
    rng = np.random.default_rng(3)
    for rid, L in enumerate([2, 3, 5, 7, 9, 11, 13, 6]):   # every length distinct mod pow2
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, CFG.vocab, size=L).astype(np.int32), max_new_tokens=5))
    done = eng.run()
    assert len(done) == 8
    bucket_bound = math.ceil(math.log2(max_len))
    assert eng._prefill._cache_size() <= bucket_bound, (
        eng._prefill._cache_size(), bucket_bound)
    n_decode = sum(fn._cache_size() for fn in eng._decode.values())
    assert n_decode <= int(math.log2(eng.drain_steps)) + 1


def test_slot_reuse_no_recurrent_state_leak():
    """Regression: recurrent carries (RG-LRU h/conv — position-less state,
    unlike position-masked KV rows) must be zeroed when a released slot is
    reused, or request B's prefill runs with request A's final hidden state
    and B's logits depend on which slot it landed in. Asserted on logits
    (the leak's perturbation is real but small enough that greedy argmax
    can mask it on a lucky prompt)."""
    from repro.models.lm import init_state, prefill_into_slot

    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                      d_ff=64, vocab=51, remat="none", dtype="float32",
                      block_pattern=("rglru",))
    params = init(cfg, jax.random.PRNGKey(1))
    prompt_a = jnp.asarray([[9, 2, 6, 5]], jnp.int32)
    prompt_b = jnp.asarray([[3, 1, 4, 1, 5]], jnp.int32)

    dirty = init_state(cfg, 2, 64)
    _, dirty = prefill_into_slot(params, cfg, prompt_a, dirty, 0, 0)
    got, _ = prefill_into_slot(params, cfg, prompt_b, dirty, 0, 0)  # reuse
    want, _ = prefill_into_slot(params, cfg, prompt_b,
                                init_state(cfg, 2, 64), 0, 0)       # fresh
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sampling_keys_advance_across_steps(setup):
    """Regression for the decode-sampling PRNG bug: the old key derivation
    ``PRNGKey(slot_pos.sum())`` repeats whenever a later request replays the
    same positions (identical prompt into the same slot), making stochastic
    sampling replay the exact same stream. The threaded engine-key chain
    must keep advancing across requests — and stay reproducible per seed."""
    params = setup
    prompt = np.array([5, 6, 7], np.int32)

    def run_two(seed):
        eng = ServeEngine(CFG, params, max_batch=2, max_len=64,
                          sampler=SamplerConfig(temperature=3.0), seed=seed)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=12))
        a = eng.run()[0].tokens
        # same prompt, same slot, same positions — old scheme replays keys
        eng.submit(Request(rid=1, prompt=prompt, max_new_tokens=12))
        b = eng.run()[0].tokens
        return a, b

    a1, b1 = run_two(seed=0)
    assert a1 != b1, "identical replay: sampling keys were reused across steps"
    a2, b2 = run_two(seed=0)
    assert (a1, b1) == (a2, b2), "same seed must reproduce the same streams"
    a3, _ = run_two(seed=1)
    assert a3 != a1, "different seeds must give different streams"


def test_snapshot_restore_determinism(setup, tmp_path):
    """A preempted engine restored from a snapshot continues mid-generation
    with token-identical output — including the stochastic sampler state."""
    params = setup

    def fresh(seed=0):
        return ServeEngine(CFG, params, max_batch=2, max_len=64,
                           sampler=SamplerConfig(temperature=0.7),
                           seed=seed, drain_steps=2)

    eng = fresh()
    eng.submit(Request(rid=0, prompt=np.array([3, 1, 4], np.int32),
                       max_new_tokens=16))
    eng.submit(Request(rid=1, prompt=np.array([1, 5, 9, 2], np.int32),
                       max_new_tokens=16))
    pre = eng.step()          # admit + a short drain; nothing completes yet
    assert not pre
    eng.snapshot(str(tmp_path), step=1)
    want = {c.rid: c.tokens for c in eng.run()}

    eng2 = fresh(seed=99)     # seed overwritten by the restored key chain
    eng2.restore(str(tmp_path))
    got = {c.rid: c.tokens for c in eng2.run()}
    assert got == want


def test_submit_validation_rejects_grid_overflow(setup):
    """Admission validation (gateway front line): an empty prompt, a
    non-positive budget, or prompt + budget past the decode grid raises at
    submit() instead of clamping into (and corrupting) the grid's last row."""
    params = setup
    eng = ServeEngine(CFG, params, max_batch=2, max_len=32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(rid=0, prompt=np.zeros(0, np.int32),
                           max_new_tokens=4))
    with pytest.raises(ValueError, match="must be >= 1"):
        eng.submit(Request(rid=1, prompt=np.array([1, 2], np.int32),
                           max_new_tokens=0))
    with pytest.raises(ValueError, match="exceeds the decode grid"):
        eng.submit(Request(rid=2, prompt=np.arange(30, dtype=np.int32) % 51,
                           max_new_tokens=8))
    assert not eng.queue, "rejected requests must not be enqueued"
    # Boundary: L + max_new == max_len is exactly representable (the last
    # generated token's KV lands in row max_len - 1) and must be accepted.
    eng.submit(Request(rid=3, prompt=np.arange(28, dtype=np.int32) % 51,
                       max_new_tokens=4))
    done = eng.run()
    assert len(done) == 1 and len(done[0].tokens) == 4


def test_cancel_queued_request(setup):
    params = setup
    eng = ServeEngine(CFG, params, max_batch=1, max_len=64)
    eng.submit(Request(rid=0, prompt=np.array([3, 1, 4], np.int32),
                       max_new_tokens=4))
    eng.submit(Request(rid=1, prompt=np.array([2, 7], np.int32),
                       max_new_tokens=4))
    assert eng.cancel(1) == "queued"
    assert eng.cancel(42) is None
    done = eng.run()
    assert [c.rid for c in done] == [0]


def test_cancel_mid_generation_frees_slot_and_preserves_survivors(setup):
    """Cancellation correctness (the gateway's deadline path): cancelling an
    active request releases its slot at the next token boundary, the next
    queued request admits into the freed slot, and every survivor's tokens
    are bit-identical to an uncancelled solo run."""
    params = setup

    def solo(prompt, n_new):
        e = ServeEngine(CFG, params, max_batch=2, max_len=64,
                        sampler=SamplerConfig(temperature=0.0))
        e.submit(Request(rid=0, prompt=prompt, max_new_tokens=n_new))
        return e.run()[0].tokens

    p_a = np.array([7, 8, 9], np.int32)
    p_b = np.array([10, 11, 12, 13], np.int32)
    p_c = np.array([3, 1, 4], np.int32)

    eng = ServeEngine(CFG, params, max_batch=2, max_len=64,
                      sampler=SamplerConfig(temperature=0.0), drain_steps=1)
    eng.submit(Request(rid=1, prompt=p_a, max_new_tokens=12))
    eng.submit(Request(rid=2, prompt=p_b, max_new_tokens=12))
    done = eng.step()                      # both admitted, generating
    assert not done
    assert eng.cancel(2) == "active"
    eng.submit(Request(rid=3, prompt=p_c, max_new_tokens=6))
    finished = {c.rid: c.tokens for c in eng.run()}
    assert set(finished) == {1, 3}, "cancelled rid 2 must never complete"
    assert finished[1] == solo(p_a, 12), "survivor perturbed by the cancel"
    assert finished[3] == solo(p_c, 6), "freed-slot occupant not bit-exact"
    assert all(r is None for r in eng.slot_req)
    assert eng.n_free_slots == 2


def test_cancel_slot_reuse_zeroes_recurrent_carries():
    """The cancel path must go through the same admission (and carry
    zeroing) as a natural release: with an RG-LRU block, the request that
    inherits a cancelled slot matches a fresh-engine run bit-exactly."""
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                      d_ff=64, vocab=51, remat="none", dtype="float32",
                      block_pattern=("rglru",))
    params = init(cfg, jax.random.PRNGKey(1))
    p_a = np.array([9, 2, 6, 5], np.int32)
    p_b = np.array([3, 1, 4, 1, 5], np.int32)

    fresh = ServeEngine(cfg, params, max_batch=1, max_len=64,
                        sampler=SamplerConfig(temperature=0.0))
    fresh.submit(Request(rid=0, prompt=p_b, max_new_tokens=6))
    want = fresh.run()[0].tokens

    eng = ServeEngine(cfg, params, max_batch=1, max_len=64,
                      sampler=SamplerConfig(temperature=0.0), drain_steps=1)
    eng.submit(Request(rid=1, prompt=p_a, max_new_tokens=12))
    eng.step()                             # A generating in slot 0
    assert eng.cancel(1) == "active"
    eng.submit(Request(rid=2, prompt=p_b, max_new_tokens=6))
    done = eng.run()
    assert [c.rid for c in done] == [2]
    assert done[0].tokens == want
