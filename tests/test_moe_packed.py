"""MoE packed fast path: expert banks on the bit-serial kernels.

Covers the PR's parity contract at <2:2>/<4:4>/<8:8>:

- routing is shared, not re-derived: the packed path's aux telemetry
  (balance loss, dropped-assignment fraction) is bit-identical to the
  float-einsum path's — same top-k, same capacity drops (the router stays
  float by design);
- the expert-stacked (E, K, N) prepack is exactly E independent
  single-bank packs (codes/planes/col_sums/wq bitwise);
- packed output tracks the float reference within the quantization-error
  envelope (which widens as bits shrink — <2:2> is a 4-level code);
- the engine surfaces the dropped-token fraction through ``stats()`` ring
  buffers (satellite: routing-overflow telemetry for the gateway);
- on a forced 8-device 4x2 (data x model) mesh (subprocess): the same
  parity holds under the expert-parallel layout, and the compiled decode
  program stays within its declared collective budget — no resharding
  beyond the dispatch all-to-all and the combine reduce (zero hot-path
  rule violations, counts flat across the drain family).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pim_layers import PIMQuantConfig
from repro.models.lm.config import ModelConfig, MoEConfig
from repro.models.lm.model import prepack_params
from repro.models.lm.moe import init_moe, moe_ffn


def _cfg(bits: int, backend: str = "int-direct") -> ModelConfig:
    return ModelConfig(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                       head_dim=32, d_ff=256, vocab=512, dtype="float32",
                       remat="none", moe=MoEConfig(n_experts=4, top_k=2),
                       pim=PIMQuantConfig(w_bits=bits, a_bits=bits,
                                          backend=backend))


# Quantization-error envelope per precision (max |packed - float| / max
# |float|): measured headroom over observed ~0.04 / ~0.53 / ~13 — the
# <2:2> code has 4 levels, so only finiteness + routing parity are
# meaningful there.
_TOL = {8: 0.15, 4: 1.0, 2: None}


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_packed_routing_bitwise_and_output_envelope(bits):
    cfg = _cfg(bits)
    p = init_moe(cfg, jax.random.PRNGKey(0))
    pp = prepack_params(p, cfg.pim)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model),
                          jnp.float32) * 0.5
    yf, af = moe_ffn(p, cfg, x)
    yp, ap = moe_ffn(pp, cfg, x)
    # Identical routing: same top-k, same drops, same balance loss — the
    # dispatch runs before the packed/float branch.
    for k in af:
        assert jnp.array_equal(af[k], ap[k]), (bits, k, af[k], ap[k])
    assert jnp.isfinite(yp).all()
    tol = _TOL[bits]
    if tol is not None:
        rel = float(jnp.abs(yp - yf).max() / (jnp.abs(yf).max() + 1e-9))
        assert rel < tol, (bits, rel)


def test_expert_stack_pack_equals_per_expert_packs():
    """The vmapped (E, K, N) prepack is E single-bank packs, bitwise."""
    from repro.core.packed import prepack

    cfg = _cfg(4)
    p = init_moe(cfg, jax.random.PRNGKey(2))
    stacked = prepack_params(p, cfg.pim)["w_in"]
    e = cfg.moe.n_experts
    for i in range(e):
        one = prepack(p["w_in"][i], cfg.pim.w_bits)
        assert jnp.array_equal(stacked.codes[i], one.codes)
        assert jnp.array_equal(stacked.planes[i], one.planes)
        assert jnp.array_equal(stacked.col_sums[i], one.col_sums)
        assert jnp.array_equal(stacked.wq.scale[i], one.wq.scale)
        assert jnp.array_equal(stacked.wq.qmin[i], one.wq.qmin)
    assert stacked.wq.bits == cfg.pim.w_bits


def test_engine_surfaces_moe_drop_fraction():
    """Routing-overflow telemetry: the MoE engine pushes per-step dropped
    fractions into a ``stats()`` ring; dense engines don't grow the key."""
    from repro.serving import Request, SamplerConfig, ServeEngine

    cfg = _cfg(8)
    from repro.models.lm import init as model_init
    params = model_init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=4, max_len=64,
                      sampler=SamplerConfig(temperature=0.0))
    rng = np.random.default_rng(0)
    for rid in range(4):
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab, size=6).astype(np.int32), max_new_tokens=5))
    done = eng.run()
    assert len(done) == 4
    st = eng.stats()
    ring = st["moe_drop_frac"]
    assert ring["n"] > 0
    assert 0.0 <= ring["mean"] <= 1.0
    for q in ("p50", "p95", "p99"):
        assert q in ring
    eng.close()

    dense = ModelConfig(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                        d_ff=128, vocab=128, remat="none", dtype="float32")
    eng2 = ServeEngine(dense, model_init(dense, jax.random.PRNGKey(1)),
                       max_batch=2, max_len=32)
    assert "moe_drop_frac" not in eng2.stats()
    eng2.close()


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.analysis import hlo
from repro.analysis.rules import run_rules
from repro.distributed import sharding as sh
from repro.launch.mesh import make_serve_mesh
from repro.models.lm import init as model_init
from repro.models.lm.model import prepack_params
from repro.models.lm.moe import init_moe, moe_ffn
from repro.core.pim_layers import PIMQuantConfig
from repro.models.lm.config import ModelConfig, MoEConfig
from repro.serving import Request, SamplerConfig, ServeEngine

def _cfg(bits):
    return ModelConfig(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                       head_dim=32, d_ff=256, vocab=512, dtype="float32",
                       remat="none", moe=MoEConfig(n_experts=4, top_k=2),
                       pim=PIMQuantConfig(w_bits=bits, a_bits=bits,
                                          backend="int-direct"))

res = {}
mesh = make_serve_mesh(2)   # 4x2 (data x model): 2 divides E=4 -> EP layout

# -- engine + compiled-collective budget on the EP mesh ----------------------
cfg = _cfg(4)
params = model_init(cfg, jax.random.PRNGKey(0))
eng = ServeEngine(cfg, params, max_batch=8, max_len=64,
                  sampler=SamplerConfig(temperature=0.0), mesh=mesh)
rng = np.random.default_rng(0)
for rid in range(8):
    eng.submit(Request(rid=rid, prompt=rng.integers(
        0, cfg.vocab, size=6).astype(np.int32), max_new_tokens=5))
res["completions"] = len(eng.run())
res["drop_ring_n"] = eng.stats()["moe_drop_frac"]["n"]
dec = next(h for h in eng.hot_paths() if h.name.startswith("lm.decode"))
res["violations"] = [f"{v.rule}:{v.where}: {v.msg[:90]}"
                     for v in run_rules(dec)]
counts = [hlo.collective_counts(p.compiled_text()) for p in dec.programs]
res["decode_collectives"] = counts[0]
res["flat"] = all(c == counts[0] for c in counts)
res["a2a_cap"] = dict(dec.budget.collectives).get("all-to-all")
eng.close()

# -- parity under the EP mesh at every precision -----------------------------
prev = sh.get_mesh()
sh.set_mesh(mesh)
try:
    for bits in (2, 4, 8):
        c = _cfg(bits)
        p = init_moe(c, jax.random.PRNGKey(1))
        x = jax.random.normal(jax.random.PRNGKey(2), (8, 4, c.d_model),
                              jnp.float32) * 0.5
        f = jax.jit(lambda pr, xr, c=c: moe_ffn(pr, c, xr))
        y1, a1 = f(prepack_params(p, c.pim), x)
        y2, _ = f(prepack_params(p, c.pim), x)
        yf, af = f(p, x)
        res[f"repack_bitwise_{bits}"] = bool(jnp.array_equal(y1, y2))
        res[f"aux_bitwise_{bits}"] = all(
            bool(jnp.array_equal(a1[k], af[k])) for k in af)
        res[f"finite_{bits}"] = bool(jnp.isfinite(y1).all())
finally:
    sh.set_mesh(prev)
print(json.dumps(res))
"""


def test_expert_parallel_mesh_subprocess():
    env = dict(os.environ, PYTHONPATH="src" + os.pathsep + ".",
               JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["completions"] == 8, res
    assert res["drop_ring_n"] > 0, res
    # Zero rule violations = collective counts within the declared EP
    # budget, gathers under the 16 KiB bound (no weight/KV resharding),
    # donation honored, no host sync.
    assert res["violations"] == [], res["violations"]
    assert res["flat"], res
    assert res["a2a_cap"] and \
        res["decode_collectives"].get("all-to-all", 0) <= res["a2a_cap"], res
    for bits in (2, 4, 8):
        assert res[f"repack_bitwise_{bits}"], res
        assert res[f"aux_bitwise_{bits}"], res
        assert res[f"finite_{bits}"], res
