import os

# Keep the smoke/bench environment at 1 device; ONLY launch/dryrun.py sets
# the 512-device host-platform flag (and does so before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "float32")
