"""NAND-SPIN fault model + self-healing serving (DESIGN.md §7).

Covers the contract triangle the fault subsystem promises:

  * determinism — same FaultConfig + seed produce bit-identical corruption,
    on one device and on a forced 8-device mesh (injection happens on the
    global-shape codes before sharding);
  * zero overhead off — a fault-free engine and a persistent-faults engine
    trace byte-identical decode HLO (faults change stored values, never the
    program), and mitigation never touches the clean path;
  * recovery — checksum detection + spare-column repair restore flagged
    columns exactly, and both serving engines survive injected mid-dispatch
    faults (rollback + retry with token parity; degradation to the float
    path once the failure budget is spent).
"""
import hashlib
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PIMQuantConfig, int_matmul_prepacked, prepack
from repro.pim.faults import (FaultConfig, inject_packed, inject_tree,
                              read_disturb_scope, repair_packed, repair_tree,
                              verify_columns)

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _pw(k=96, n=48, bits=8, seed=0):
    rng = np.random.default_rng(seed)
    return prepack(jnp.asarray(rng.standard_normal((k, n)), jnp.float32), bits)


# -- deterministic injection -------------------------------------------------

def test_injection_deterministic():
    pw = _pw()
    cfg = FaultConfig(write_ber=1e-2, retention_ber=1e-3, stuck0_rate=1e-3,
                      stuck1_rate=1e-3, seed=3)
    a = inject_packed(pw, cfg, cfg.key())
    b = inject_packed(pw, cfg, cfg.key())
    np.testing.assert_array_equal(np.asarray(a.codes), np.asarray(b.codes))
    np.testing.assert_array_equal(np.asarray(a.planes), np.asarray(b.planes))
    c = inject_packed(pw, cfg, FaultConfig(write_ber=1e-2, seed=4).key())
    assert (np.asarray(a.codes) != np.asarray(c.codes)).any()
    # corruption touched something, and col_sums stayed golden
    assert (np.asarray(a.codes) != np.asarray(pw.codes)).any()
    np.testing.assert_array_equal(np.asarray(a.col_sums),
                                  np.asarray(pw.col_sums))


_SUBPROC_INJECT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import hashlib, json
import jax
from repro.launch.mesh import make_serve_mesh
from repro.models.lm import ModelConfig, init
from repro.models.lm.model import prepack_params
from repro.core import PIMQuantConfig
from repro.core.packed import PackedWeight
from repro.pim.faults import FaultConfig

cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab=61, remat="none", dtype="float32",
                  pim=PIMQuantConfig(w_bits=4, a_bits=4, backend="popcount"))
params = init(cfg, jax.random.PRNGKey(0))
packed = prepack_params(params, cfg.pim, mesh=make_serve_mesh(2),
                        faults=FaultConfig(write_ber=3e-3, seed=9))
hashes = {}
def walk(p, path):
    if isinstance(p, PackedWeight):
        import numpy as np
        hashes[path] = hashlib.sha1(
            np.asarray(jax.device_get(p.codes)).tobytes()).hexdigest()
    elif isinstance(p, dict):
        for k, v in p.items():
            walk(v, f"{path}/{k}")
    elif isinstance(p, (list, tuple)):
        for i, v in enumerate(p):
            walk(v, f"{path}/{i}")
walk(packed, "")
print(json.dumps(hashes))
"""


def test_injection_matches_across_device_count():
    """Faults are drawn on the global-shape codes before sharding, so the
    corruption pattern is a function of (config, seed) alone: an 8-device
    mesh-sharded prepack and this process's single-device prepack hash
    identically, leaf by leaf."""
    from repro.core.packed import PackedWeight
    from repro.models.lm import ModelConfig, init
    from repro.models.lm.model import prepack_params

    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=61, remat="none", dtype="float32",
                      pim=PIMQuantConfig(w_bits=4, a_bits=4,
                                         backend="popcount"))
    params = init(cfg, jax.random.PRNGKey(0))
    packed = prepack_params(params, cfg.pim,
                            faults=FaultConfig(write_ber=3e-3, seed=9))
    local = {}

    def walk(p, path):
        if isinstance(p, PackedWeight):
            local[path] = hashlib.sha1(
                np.asarray(jax.device_get(p.codes)).tobytes()).hexdigest()
        elif isinstance(p, dict):
            for k, v in p.items():
                walk(v, f"{path}/{k}")
        elif isinstance(p, (list, tuple)):
            for i, v in enumerate(p):
                walk(v, f"{path}/{i}")

    walk(packed, "")
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC_INJECT],
                         capture_output=True, text=True, env=env, cwd=_REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    remote = json.loads(out.stdout.strip().splitlines()[-1])
    assert local and remote == local


# -- cross-backend parity under corruption -----------------------------------

def test_backend_parity_under_persistent_faults():
    """Corruption is computed on the codes and re-rendered into every
    stored representation, so all Eq. 1 backends agree bit-for-bit on the
    *corrupted* product — the fault model never breaks backend parity."""
    pw = _pw(k=64, n=32, bits=4)
    cfg = FaultConfig(write_ber=2e-2, stuck1_rate=5e-3, seed=7)
    bad = inject_packed(pw, cfg, cfg.key())
    rng = np.random.default_rng(1)
    qa = jnp.asarray(rng.integers(0, 16, size=(8, 64)), jnp.int32)
    outs = {b: np.asarray(int_matmul_prepacked(qa, bad, 4, backend=b))
            for b in ("int-direct", "mxu-plane", "popcount")}
    clean = np.asarray(int_matmul_prepacked(qa, pw, 4, backend="popcount"))
    assert (outs["popcount"] != clean).any()
    np.testing.assert_array_equal(outs["int-direct"], outs["mxu-plane"])
    np.testing.assert_array_equal(outs["int-direct"], outs["popcount"])


def test_backend_parity_under_read_disturb():
    """Inside one read_disturb_scope position, every backend sees the same
    disturbed device state; the same (config, key) reproduces it exactly."""
    pw = _pw(k=64, n=32, bits=4)
    cfg = FaultConfig(read_disturb_ber=5e-3, seed=2)
    rng = np.random.default_rng(1)
    qa = jnp.asarray(rng.integers(0, 16, size=(8, 64)), jnp.int32)
    key = jax.random.PRNGKey(5)

    def run(backend):
        with read_disturb_scope(cfg, key):
            return np.asarray(int_matmul_prepacked(qa, pw, 4,
                                                   backend=backend))

    a, b, c = run("int-direct"), run("mxu-plane"), run("popcount")
    clean = np.asarray(int_matmul_prepacked(qa, pw, 4, backend="popcount"))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)
    np.testing.assert_array_equal(a, run("int-direct"))   # same key -> same
    assert (a != clean).any()
    with read_disturb_scope(cfg, jax.random.PRNGKey(6)):
        other = np.asarray(int_matmul_prepacked(qa, pw, 4,
                                                backend="popcount"))
    assert (a != other).any()


# -- checksum detection + spare repair ----------------------------------------

def test_checksum_detects_and_repair_restores():
    pw = _pw(k=32, n=16, bits=8)
    cfg = FaultConfig(write_ber=1e-2, seed=0)
    bad = inject_packed(pw, cfg, cfg.key())
    flagged = np.asarray(verify_columns(bad))
    assert flagged.any()
    fixed, n_bad, n_fix = repair_packed(bad, pw, spare_cols=16)
    assert n_bad == int(flagged.sum()) and n_fix == n_bad
    # every flagged column restored exactly; unflagged columns untouched
    diff = (np.asarray(fixed.codes) != np.asarray(pw.codes)).any(axis=-2)
    assert not (diff & flagged).any()
    assert not np.asarray(verify_columns(fixed)).any()


def test_repair_budget_is_per_subarray():
    pw = _pw(k=32, n=16, bits=8)
    golden = np.asarray(pw.codes)
    # two corrupt columns in each 8-column subarray group
    codes = golden.copy()
    for col in (1, 5, 9, 13):
        codes[0, col] += 3
    from repro.core.packed import repack_codes

    bad = repack_codes(pw, jnp.asarray(codes))
    # leaf-wide budget of 2 repairs only the first two flagged columns
    _, n_bad, n_fix = repair_packed(bad, pw, spare_cols=2)
    assert (n_bad, n_fix) == (4, 2)
    # per-subarray budget of 1: one repair in EACH 8-column group
    fixed, n_bad, n_fix = repair_packed(bad, pw, spare_cols=1,
                                        subarray_cols=8)
    assert (n_bad, n_fix) == (4, 2)
    still = np.asarray(verify_columns(fixed))
    assert list(np.nonzero(still)[0]) == [5, 13]


def test_inject_tree_reports_and_repairs():
    tree = {"a": _pw(seed=1), "b": [_pw(seed=2), {"w": _pw(seed=3)}]}
    cfg = FaultConfig(write_ber=5e-3, checksum=True, spare_cols=64, seed=8)
    out, rep = inject_tree(tree, cfg)
    assert rep["injected"] == 3 and rep["bad_cols"] > 0
    assert rep["repaired_cols"] == rep["bad_cols"]  # budget covers all
    # repair_tree against the golden tree is then a no-op
    again, rep2 = repair_tree(out, tree, 64)
    assert rep2["repaired_cols"] == 0


# -- zero overhead when disabled ---------------------------------------------

def test_decode_hlo_identical_with_persistent_faults():
    """Persistent faults corrupt stored values, never the traced program:
    the decode HLO of a fault-injected engine is byte-identical to the
    fault-free engine's. (Transient disturb is the one thing that changes
    the program, and it is gated on cfg.transient.)"""
    from repro.models.lm import ModelConfig, init
    from repro.serving import SamplerConfig, ServeEngine

    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=61, remat="none", dtype="float32")
    params = init(cfg, jax.random.PRNGKey(0))

    from repro.analysis import hlo as H

    def fingerprint(faults):
        eng = ServeEngine(cfg, params, max_batch=4, max_len=32,
                          sampler=SamplerConfig(temperature=0.0),
                          faults=faults)
        return H.lowered_text(eng._decode_fn(4),
                              eng.params, eng.state, eng.ctrl)

    assert fingerprint(None) == fingerprint(FaultConfig(write_ber=1e-2,
                                                        seed=1))


# -- self-healing LM engine ---------------------------------------------------

def _lm_workload(eng):
    from repro.serving import Request

    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([7, 8], np.int32),
               np.array([9, 2, 6], np.int32)]
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
    return {c.rid: c.tokens for c in eng.run()}


def test_engine_rollback_retry_token_parity():
    """A fault injected mid-decode rolls back to the shadow snapshot and
    retries; the served tokens are identical to the fault-free run."""
    from repro.models.lm import ModelConfig, init
    from repro.serving import SamplerConfig, ServeEngine
    from repro.training.fault_tolerance import WatchdogConfig

    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=61, remat="none", dtype="float32")
    params = init(cfg, jax.random.PRNGKey(0))
    base = _lm_workload(ServeEngine(cfg, params, max_batch=4, max_len=32,
                                    sampler=SamplerConfig(temperature=0.0)))

    boom = {"armed": True}

    def injector(dispatch):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected mid-decode fault")

    eng = ServeEngine(cfg, params, max_batch=4, max_len=32,
                      sampler=SamplerConfig(temperature=0.0),
                      watchdog=WatchdogConfig(max_failures=3, backoff_s=0.01),
                      fault_injector=injector)
    assert _lm_workload(eng) == base
    assert eng.health["rollbacks"] >= 1 and eng.health["dispatches"] >= 1
    assert not eng.health["degraded"]


def test_engine_degrades_to_float_under_sustained_faults():
    """Once the failure budget is spent the engine drops to the float
    fallback path and keeps serving instead of crashing."""

    from repro.models.lm import ModelConfig, init
    from repro.serving import SamplerConfig, ServeEngine
    from repro.training.fault_tolerance import WatchdogConfig

    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=61, remat="none", dtype="float32",
                      pim=PIMQuantConfig(w_bits=4, a_bits=4,
                                         backend="int-direct"))
    params = init(cfg, jax.random.PRNGKey(0))
    fails = {"n": 0}

    def injector(dispatch):
        if fails["n"] < 3:
            fails["n"] += 1
            raise RuntimeError("sustained fault")

    eng = ServeEngine(cfg, params, max_batch=4, max_len=32,
                      sampler=SamplerConfig(temperature=0.0),
                      watchdog=WatchdogConfig(max_failures=2, backoff_s=0.01,
                                              degrade=True),
                      fault_injector=injector)
    done = _lm_workload(eng)
    assert sorted(done) == [0, 1, 2]
    assert eng.health["degraded"] and not eng.cfg.pim.enabled


# -- self-healing vision engine ----------------------------------------------

@pytest.fixture(scope="module")
def alexnet_setup():
    from repro.models.cnn import alexnet

    key = jax.random.PRNGKey(0)
    params = alexnet.init(key, num_classes=16, image=64)
    imgs = [np.asarray(jax.random.normal(jax.random.fold_in(key, i),
                                         (64, 64, 3))) for i in range(4)]
    return alexnet, params, imgs


def _vision_engine(alexnet_setup, **kw):
    from repro.serving.vision import VisionEngine, VisionRequest

    module, params, imgs = alexnet_setup
    eng = VisionEngine({"alexnet": (module, params)}, backend="int-direct",
                       max_batch=kw.pop("max_batch", 4), **kw)
    for i, im in enumerate(imgs):
        eng.submit(VisionRequest(rid=i, image=im, model="alexnet",
                                 precision="<8:8>"))
    return eng


def test_vision_repair_on_retry(alexnet_setup):
    """A failed bucket triggers a checksum scan: flagged columns re-program
    from the golden tree before the retry."""
    from repro.training.fault_tolerance import WatchdogConfig

    fc = FaultConfig(write_ber=5e-3, checksum=True, spare_cols=64, seed=3)
    boom = {"armed": True}

    def injector(dispatch):
        if dispatch == 1 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected vision fault")

    eng = _vision_engine(alexnet_setup, max_batch=2, faults=fc,
                         watchdog=WatchdogConfig(max_failures=3,
                                                 backoff_s=0.01),
                         fault_injector=injector)
    done = eng.run(strict=True)
    assert sorted(c.rid for c in done) == [0, 1, 2, 3]
    assert eng.health["rollbacks"] >= 1
    assert eng.health["repairs"] >= 1 and eng.health["repaired_cols"] > 0


def test_vision_degrades_cohort_to_float(alexnet_setup):
    """Sustained failures degrade the (model, precision) cohort to the
    float path; its completions match the clean float engine's."""
    from repro.training.fault_tolerance import WatchdogConfig

    base = {c.rid: c.top1 for c in _vision_engine(alexnet_setup).run()}

    def injector(dispatch):
        raise RuntimeError("sustained vision fault")

    fc = FaultConfig(write_ber=5e-3, checksum=True, spare_cols=64, seed=3)
    eng = _vision_engine(alexnet_setup, faults=fc,
                         watchdog=WatchdogConfig(max_failures=2,
                                                 backoff_s=0.01),
                         fault_injector=injector)
    out = {c.rid: c.top1 for c in eng.run()}
    assert eng.health["degraded"] == [("alexnet", "<8:8>")]
    assert set(out) == set(base)
    assert len(eng.queue) == 0


def test_run_warns_on_stranded_requests(alexnet_setup):
    import warnings

    eng = _vision_engine(alexnet_setup)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng.run(max_steps=0)
    assert any("still queued" in str(x.message) for x in w)
    with pytest.raises(RuntimeError, match="still queued"):
        _vision_engine(alexnet_setup).run(max_steps=0, strict=True)
