"""Vision serving engine (DESIGN.md §6): bit-identity with direct
``model.apply``, power-of-two micro-batch bucketing, prepack-once caching,
and the mesh-sharded conv layout with its no-large-all-gather invariant.

Mesh-path coverage mirrors tests/test_serve_sharded.py: in-process tests
need a multi-device host (the mesh8 CI job), and an always-run subprocess
forces an 8-device world so the default tier-1 suite covers the sharded
vision path too.
"""
import json
import os
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PIMQuantConfig
from repro.models.cnn import alexnet
from repro.models.cnn import layers as L
from repro.serving import VisionEngine, VisionRequest, parse_precision

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

needs2 = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


# -- a small CNN so quantized forwards stay cheap ---------------------------

def _mini_init(key, image=16, num_classes=16):
    return {
        "c1": L.init_conv(jax.random.fold_in(key, 0), 3, 3, 32),
        "c2": L.init_conv(jax.random.fold_in(key, 1), 3, 32, 64, bn=False),
        "head": L.init_fc(jax.random.fold_in(key, 2), 64, num_classes),
    }


def _mini_apply(params, x, cfg=None, train=False):
    x = L.conv_block(params["c1"], x, stride=1, padding=1, cfg=cfg, train=train)
    x = L.conv_block(params["c2"], x, stride=2, padding=1, cfg=cfg, train=train)
    x = L.avg_pool_global(x)
    return L.fc_block(params["head"], x, cfg=cfg, relu=False, train=train)


MINI = types.SimpleNamespace(init=_mini_init, apply=_mini_apply)


@pytest.fixture(scope="module")
def mini_params():
    return _mini_init(jax.random.PRNGKey(0))


def _images(n, image=16, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, image, image, 3)).astype(np.float32)


# -- bit-identity vs direct model.apply -------------------------------------

@pytest.mark.parametrize("backend", ["int-direct", "popcount"])
def test_engine_bit_identical_to_direct_apply_quantized(mini_params, backend):
    """A bucket's logits == jitted model.apply on the same stacked batch
    with the same PIMQuantConfig and the same prepacked weights (prepack is
    deterministic, so an independent prepack is the same tree)."""
    cfg = PIMQuantConfig(w_bits=4, a_bits=4, backend=backend)
    imgs = _images(4)
    eng = VisionEngine({"mini": (MINI, mini_params)}, backend=backend,
                       max_batch=4)
    for i in range(4):
        eng.submit(VisionRequest(rid=i, image=imgs[i], model="mini",
                                 precision="<4:4>"))
    got = {c.rid: c.logits for c in eng.run()}
    pk = L.prepack_params(mini_params, cfg)
    ref = jax.jit(lambda p, x: _mini_apply(p, x, cfg=cfg))(
        pk, jnp.asarray(imgs))
    for i in range(4):
        assert np.array_equal(got[i], np.asarray(ref[i]))


def test_engine_bit_identical_to_direct_apply_float(mini_params):
    """precision=None serves the float forward, bit-identical to jitted
    model.apply with cfg=None."""
    imgs = _images(4, seed=1)
    eng = VisionEngine({"mini": (MINI, mini_params)}, max_batch=4)
    for i in range(4):
        eng.submit(VisionRequest(rid=i, image=imgs[i], model="mini",
                                 precision=None))
    got = {c.rid: c.logits for c in eng.run()}
    ref = jax.jit(lambda p, x: _mini_apply(p, x, cfg=None))(
        mini_params, jnp.asarray(imgs))
    for i in range(4):
        assert np.array_equal(got[i], np.asarray(ref[i]))


def test_engine_zoo_model_bit_identical():
    """Zoo registry path (params-only, name resolved): alexnet through the
    engine == jitted alexnet.apply on the prepacked tree."""
    params = alexnet.init(jax.random.PRNGKey(0), image=64, num_classes=10)
    cfg = PIMQuantConfig(w_bits=8, a_bits=8, backend="int-direct")
    imgs = _images(2, image=64, seed=2)
    eng = VisionEngine({"alexnet": params}, max_batch=2)
    for i in range(2):
        eng.submit(VisionRequest(rid=i, image=imgs[i], model="alexnet",
                                 precision="<8:8>"))
    got = {c.rid: c.logits for c in eng.run()}
    ref = jax.jit(lambda p, x: alexnet.apply(p, x, cfg=cfg))(
        alexnet.prepack(params, cfg), jnp.asarray(imgs))
    for i in range(2):
        assert np.array_equal(got[i], np.asarray(ref[i]))


# -- micro-batching ----------------------------------------------------------

def test_pow2_bucketing_and_bounded_compiles(mini_params):
    """6 queued -> buckets of 4 and 2; a varied load compiles at most
    log2(max_batch)+1 forward variants per (model, precision)."""
    eng = VisionEngine({"mini": (MINI, mini_params)}, max_batch=4)
    imgs = _images(6, seed=3)
    for i in range(6):
        eng.submit(VisionRequest(rid=i, image=imgs[i], model="mini",
                                 precision="<4:4>"))
    done = eng.run()
    buckets = [c.batch for c in sorted(done, key=lambda c: c.rid)]
    assert buckets == [4, 4, 4, 4, 2, 2]
    assert sorted(b for (_, _, b) in eng._fwd) == [2, 4]
    # same-shaped traffic reuses the compiled variants
    for i in range(6):
        eng.submit(VisionRequest(rid=10 + i, image=imgs[i], model="mini",
                                 precision="<4:4>"))
    eng.run()
    assert sorted(b for (_, _, b) in eng._fwd) == [2, 4]


def test_mixed_precision_cohorts_group_separately(mini_params):
    """Interleaved precisions serve in per-(model, precision) buckets."""
    eng = VisionEngine({"mini": (MINI, mini_params)}, max_batch=8)
    imgs = _images(8, seed=4)
    precs = ["<4:4>", "<8:8>", "<4:4>", None, "<4:4>", "<8:8>", "<4:4>", None]
    for i in range(8):
        eng.submit(VisionRequest(rid=i, image=imgs[i], model="mini",
                                 precision=precs[i]))
    done = {c.rid: c for c in eng.run()}
    assert len(done) == 8
    # the 4-strong <4:4> cohort rides one bucket of 4; the pairs ride 2s
    assert [done[i].batch for i in (0, 2, 4, 6)] == [4, 4, 4, 4]
    assert [done[i].batch for i in (1, 5)] == [2, 2]
    assert [done[i].batch for i in (3, 7)] == [2, 2]


def test_prepack_exactly_once_per_model_cfg(mini_params, monkeypatch):
    """Repeated buckets of one (model, precision) quantize+pack weights
    exactly once — the paper's program-subarrays-once property."""
    from repro.serving import vision as V

    calls = []
    orig = V._prepack_cnn
    monkeypatch.setattr(V, "_prepack_cnn",
                        lambda p, cfg: (calls.append(1), orig(p, cfg))[1])
    eng = VisionEngine({"mini": (MINI, mini_params)}, max_batch=2)
    imgs = _images(6, seed=5)
    for i in range(6):
        eng.submit(VisionRequest(rid=i, image=imgs[i], model="mini",
                                 precision="<4:4>"))
    eng.run()
    assert len(calls) == 1
    # a second precision packs its own tree, again exactly once
    for i in range(4):
        eng.submit(VisionRequest(rid=10 + i, image=imgs[i], model="mini",
                                 precision="<8:8>"))
    eng.run()
    assert len(calls) == 2


# -- admission validation ----------------------------------------------------

def test_admission_validation(mini_params):
    eng = VisionEngine({"mini": (MINI, mini_params)})
    with pytest.raises(ValueError, match="unknown model"):
        eng.submit(VisionRequest(rid=0, image=_images(1)[0], model="nope"))
    with pytest.raises(ValueError, match="precision"):
        eng.submit(VisionRequest(rid=0, image=_images(1)[0], model="mini",
                                 precision="8x8"))
    assert parse_precision("<8:4>") == (8, 4)
    assert parse_precision(None) is None
    with pytest.raises(ValueError, match="unknown model"):
        VisionEngine({"not-in-zoo": mini_params})


def test_pallas_backend_rejected_on_mesh(mini_params):
    """pallas_call has no GSPMD rule — the engine must refuse it with a
    mesh instead of silently all-gathering the split planes per bucket."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (mesh8 CI job)")
    from repro.launch.mesh import make_serve_mesh

    with pytest.raises(ValueError, match="pallas"):
        VisionEngine({"mini": (MINI, mini_params)}, backend="pallas",
                     mesh=make_serve_mesh(2))


# -- mesh-sharded path (multi-device host) ----------------------------------

@needs2
def test_shard_packed_conv_layout(mini_params):
    """PackedConvWeight shards on the bank (output-channel) mapping: mat
    planes/codes/col_sums on N, fused_planes on O; split='k' is rejected."""
    from jax.sharding import PartitionSpec as P

    from repro.core.packed import prepack_conv, shard_packed
    from repro.launch.mesh import make_serve_mesh

    mesh = make_serve_mesh(2)
    w = jax.random.normal(jax.random.PRNGKey(6), (3, 3, 16, 32))
    pk = prepack_conv(w, 4)
    pks = shard_packed(pk, mesh, axis="model", split="n")
    assert pks.fused_planes.sharding.spec == P(None, None, "model", None, None)
    assert pks.mat.planes.sharding.spec == P(None, "model", None)
    assert pks.mat.codes.sharding.spec == P(None, "model")
    assert pks.mat.col_sums.sharding.spec == P("model")
    assert np.array_equal(np.asarray(pks.to_float()), np.asarray(pk.to_float()))
    with pytest.raises(ValueError, match="split"):
        shard_packed(pk, mesh, split="k")


@needs2
def test_serve_cnn_param_shardings_rules(mini_params):
    """Quantized trees split every weight representation and the per-channel
    epilogue vectors on "model"; float trees replicate (DP-only serving)."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_serve_mesh

    mesh = make_serve_mesh(2)
    cfg = PIMQuantConfig(w_bits=4, a_bits=4, backend="int-direct")
    pk = L.prepack_params(mini_params, cfg)
    shardings = sh.serve_cnn_param_shardings(pk, mesh, quantized=True)
    assert shardings["c1"]["w"].fused_planes.spec == \
        P(None, None, "model", None, None)
    assert shardings["c1"]["w"].mat.planes.spec == P(None, "model", None)
    assert shardings["c1"]["gamma"].spec == P("model")
    assert shardings["head"]["w"].planes.spec == P(None, "model", None)
    flt = sh.serve_cnn_param_shardings(mini_params, mesh, quantized=False)
    assert all(s.spec == P() for s in jax.tree.leaves(flt))


@needs2
@pytest.mark.parametrize("backend,precision", [
    ("int-direct", "<4:4>"), ("popcount", "<4:4>"), ("int-direct", None)])
def test_mesh_engine_matches_direct_apply_and_single_device(
        mini_params, backend, precision):
    """On the mesh the serving machinery stays numerics-transparent: bucket
    logits are bit-identical to direct jitted ``model.apply`` under the
    same deployment shardings. Across device topologies, the float path
    (fully replicated) stays bit-identical to the single-device engine; the
    quantized paths' integer core is partition-exact but their float
    dequantization epilogue is compiled with topology-dependent FMA
    contraction (ULP-level), so cross-topology parity there is top-1 plus
    allclose — same contract as the LM engine's token-level parity."""
    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_serve_mesh

    imgs = _images(8, seed=7)

    def run(mesh):
        eng = VisionEngine({"mini": (MINI, mini_params)}, backend=backend,
                           max_batch=8, mesh=mesh)
        for i in range(8):
            eng.submit(VisionRequest(rid=i, image=imgs[i], model="mini",
                                     precision=precision))
        return eng, {c.rid: c.logits for c in eng.run()}

    mesh = make_serve_mesh(2)
    eng, shard = run(mesh)
    assert sh.get_mesh() is None, "engine leaked its mesh into global state"
    assert not sh.get_cnn_serve_layout(), "engine leaked the CNN layout flag"

    # direct model.apply, jitted under the engine's deployment shardings —
    # bit-identical: batching/caching/donation add no numerics.
    cfg = eng._cfg(precision)
    quantized = cfg is not None
    params = eng._packed_params("mini", precision)
    if quantized:
        batch_sh = sh.serve_cnn_batch_sharding(mesh, 8)
        out_sh = sh.serve_cnn_logits_sharding(mesh, 8)
    else:
        batch_sh = out_sh = sh.replicated(mesh)
    with eng._activate(quantized):
        ref = jax.jit(lambda p, x: _mini_apply(p, x, cfg=cfg),
                      in_shardings=(eng._param_sh[("mini", precision)],
                                    batch_sh),
                      out_shardings=out_sh)(
            params, jax.device_put(jnp.asarray(imgs), batch_sh))
    ref = np.asarray(ref)
    for i in range(8):
        assert np.array_equal(shard[i], ref[i]), (backend, precision, i)

    _, plain = run(None)
    for i in range(8):
        if precision is None:
            assert np.array_equal(shard[i], plain[i]), i
        else:
            assert np.argmax(shard[i]) == np.argmax(plain[i]), i
            np.testing.assert_allclose(shard[i], plain[i], rtol=1e-4,
                                       atol=1e-3)


# -- the no-resharding HLO invariant ----------------------------------------
#
# The gather-size regex that used to live here is now
# repro.analysis.hlo.gather_sizes, and the bound/no-all-to-all assertions
# are the `collective-budget` rule run over the engine's own HotPath
# declarations — one implementation shared with the CI lint gate.


@needs2
def test_cnn_forward_hlo_no_large_gather(mini_params):
    """The bucket forward keeps weights resident: the only cross-shard
    movement is the activation-map redistribution between bank-split convs
    (the paper's transfer phase). Nothing patch-matrix- or weight-sized
    gathers, and there is no all-to-all. The float forward is fully
    replicated — zero all-gathers."""
    from repro import analysis
    from repro.analysis import hlo
    from repro.launch.mesh import make_serve_mesh

    mesh = make_serve_mesh(2)
    eng = VisionEngine({"mini": (MINI, mini_params)}, backend="int-direct",
                       max_batch=8, mesh=mesh)
    try:
        hps = eng.hot_paths(shapes={("mini", "<4:4>", 8): (16, 16, 3),
                                    ("mini", None, 8): (16, 16, 3)})
        caps = {hp.name: hp.budget.max_gather_bytes for hp in hps}
        # float path declares full replication: zero gathers allowed
        assert caps["cnn.fwd[mini,float,b=8]"] == 0
        # quantized budget = one activation map at the widest channel count
        # (c2's 64 outputs); the 9x-larger patch matrix is far beyond it
        assert caps["cnn.fwd[mini,<4:4>,b=8]"] == 4 * 8 * 16 * 16 * 64
        viols = analysis.lint_hot_paths(hps)
        assert not viols, analysis.format_report(viols)

        # the executed program in fact stays within the tighter regime of
        # c2's 32-channel *input* map — check via the shared size parser
        act_bytes = 4 * 8 * 16 * 16 * 32
        quant = next(hp for hp in hps if "<4:4>" in hp.name)
        sizes = hlo.gather_sizes(quant.programs[0].compiled_text())
        assert all(s <= act_bytes for s in sizes), \
            f"gather larger than an activation map: {sorted(sizes)[-3:]}"
    finally:
        eng.close()


# -- always-run subprocess coverage -----------------------------------------

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, numpy as np, jax.numpy as jnp
from repro import analysis
from repro.distributed import sharding as sh
from repro.launch.mesh import make_serve_mesh
from tests.test_vision_engine import MINI, _images, _mini_init
from repro.serving import VisionEngine, VisionRequest

params = _mini_init(jax.random.PRNGKey(0))
imgs = _images(8, seed=7)

def run(mesh, backend, precision):
    eng = VisionEngine({"mini": (MINI, params)}, backend=backend,
                       max_batch=8, mesh=mesh)
    for i in range(8):
        eng.submit(VisionRequest(rid=i, image=imgs[i], model="mini",
                                 precision=precision))
    return eng, {c.rid: c.logits for c in eng.run()}

out = {"parity": {}, "violations": [], "leak": False}
mesh = make_serve_mesh(2)
for backend, prec in [("int-direct", "<4:4>"), ("popcount", "<4:4>"),
                      ("int-direct", None)]:
    eng, shard = run(mesh, backend, prec)
    out["leak"] = out["leak"] or sh.get_mesh() is not None
    cfg = eng._cfg(prec)
    quantized = cfg is not None
    tree = eng._packed_params("mini", prec)   # do NOT shadow global params
    if quantized:
        batch_sh = sh.serve_cnn_batch_sharding(mesh, 8)
        out_sh = sh.serve_cnn_logits_sharding(mesh, 8)
    else:
        batch_sh = out_sh = sh.replicated(mesh)
    with eng._activate(quantized):
        ref = jax.jit(lambda p, x: MINI.apply(p, x, cfg=cfg),
                      in_shardings=(eng._param_sh[("mini", prec)], batch_sh),
                      out_shardings=out_sh)(
            tree, jax.device_put(jnp.asarray(imgs), batch_sh))
    ref = np.asarray(ref)
    _, plain = run(None, backend, prec)
    # engine == direct apply under the same shardings, bitwise; across
    # topologies float is bitwise, quantized is top1 + allclose (the int
    # core is partition-exact; the dequant epilogue is FMA-sensitive).
    cross = (all(np.array_equal(shard[i], plain[i]) for i in range(8))
             if prec is None else
             all(np.argmax(shard[i]) == np.argmax(plain[i])
                 and np.allclose(shard[i], plain[i], rtol=1e-4, atol=1e-3)
                 for i in range(8)))
    out["parity"][f"{backend}/{prec}"] = cross and all(
        np.array_equal(shard[i], ref[i]) for i in range(8))

# lint every dispatched bucket of the sharded engine with the shared
# collective-budget rule (gather bound + no all-to-all)
eng, _ = run(mesh, "int-direct", "<4:4>")
viols = analysis.lint_hot_paths(eng.hot_paths(),
                                rules=("collective-budget",))
out["violations"] = [str(v) for v in viols]
print(json.dumps(out))
"""


def test_mesh_vision_subprocess():
    """Tier-1 coverage without a multi-device parent: force 8 host devices
    in a child and check bit-parity (int-direct, popcount, float) plus the
    collective-budget invariant."""
    env = dict(os.environ, PYTHONPATH="src" + os.pathsep + ".",
               JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, env=env, cwd=_REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert not res["leak"], "engine leaked its mesh"
    assert all(res["parity"].values()), res["parity"]
    assert not res["violations"], res["violations"]
