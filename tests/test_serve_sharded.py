"""Mesh-sharded serving: token parity vs the single-device engine, the
steady-state no-resharding HLO invariant, and the shard_map bit-serial
kernel (DESIGN.md §5).

The in-process tests need a multi-device host and skip on a 1-device run;
CI exercises them under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the mesh8 job). ``test_sharded_serving_subprocess`` always runs: it forces
the 8-device world in a child process, so the default tier-1 suite covers
the mesh path too.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import sharding as sh
from repro.models.lm import ModelConfig, init
from repro.serving import Request, SamplerConfig, ServeEngine

CFG = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab=61, remat="none", dtype="float32")

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def params():
    return init(CFG, jax.random.PRNGKey(0))


@pytest.fixture()
def serve_mesh():
    from repro.launch.mesh import make_serve_mesh

    return make_serve_mesh(2)   # (data=4, model=2)


def _workload(eng):
    """Mixed prompt lengths, staggered submits, EOS mid-stream."""
    prompts = {
        0: np.array([3, 1, 4, 1, 5], np.int32),
        1: np.array([7, 8], np.int32),
        2: np.array([9, 2, 6, 5, 3, 5, 8], np.int32),
        3: np.array([11, 12, 13], np.int32),
        4: np.array([17, 19, 23, 29, 31, 37], np.int32),
    }
    eng.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=6))
    eng.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=5))
    done = eng.step() + eng.step()
    # rid 2 gets an eos id the greedy stream is likely to hit mid-stream; a
    # fixed token works because parity only needs both engines to see it.
    eng.submit(Request(rid=2, prompt=prompts[2], max_new_tokens=8, eos_id=39))
    eng.submit(Request(rid=3, prompt=prompts[3], max_new_tokens=6))
    eng.submit(Request(rid=4, prompt=prompts[4], max_new_tokens=4))
    done += eng.run()
    return {c.rid: c.tokens for c in done}


@needs8
def test_sharded_token_parity(params, serve_mesh):
    """Sharded serving on a (4, 2) mesh is token-identical to the
    single-device engine across mixed prompts, slot reuse and EOS.

    The sharded engine runs FIRST: its mesh activation must be scoped to
    its own program calls (engine._activate), so the mesh-free engine built
    afterwards — with no defensive set_mesh(None) — must not inherit it."""
    shard = _workload(ServeEngine(CFG, params, max_batch=4, max_len=64,
                                  sampler=SamplerConfig(temperature=0.0),
                                  mesh=serve_mesh))
    assert sh.get_mesh() is None, "engine leaked its mesh into global state"
    plain = _workload(ServeEngine(CFG, params, max_batch=4, max_len=64,
                                  sampler=SamplerConfig(temperature=0.0)))
    assert plain == shard


@needs8
def test_sharded_pim_popcount_parity(params, serve_mesh):
    """The quantized serving path (paper dataflow, popcount backend) stays
    bit-exact under sharding: integer popcount partials and the affine
    correction partition without changing any arithmetic."""
    import dataclasses

    from repro.core.pim_layers import PIMQuantConfig

    cfg = dataclasses.replace(
        CFG, pim=PIMQuantConfig(w_bits=4, a_bits=4, backend="popcount"))
    reqs = [np.array([3, 1, 4, 1, 5], np.int32), np.array([7, 8], np.int32)]

    def run(mesh):
        eng = ServeEngine(cfg, params, max_batch=4, max_len=32,
                          sampler=SamplerConfig(temperature=0.0), mesh=mesh)
        for rid, p in enumerate(reqs):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=4))
        return {c.rid: c.tokens for c in eng.run()}

    assert run(serve_mesh) == run(None)


def test_pallas_backend_rejected_on_mesh(params):
    """pallas_call has no GSPMD rule — the engine must refuse the silent
    all-gather-every-step combination instead of running it."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (mesh8 CI job)")
    import dataclasses

    from repro.core.pim_layers import PIMQuantConfig
    from repro.launch.mesh import make_serve_mesh

    cfg = dataclasses.replace(
        CFG, pim=PIMQuantConfig(w_bits=4, a_bits=4, backend="pallas"))
    with pytest.raises(ValueError, match="pallas"):
        ServeEngine(cfg, params, max_batch=4, max_len=32,
                    mesh=make_serve_mesh(2))


# -- steady-state HLO invariant ---------------------------------------------
#
# The gather-size / collective-count / flatness assertions that used to live
# here as module-level regex helpers are now the `collective-budget` rule of
# repro.analysis — this test lints the engine's own HotPath declaration, so
# the suite and the `python -m repro.analysis lint` CI gate share one
# implementation.


@needs8
def test_decode_hlo_no_resharding(params, serve_mesh):
    """Steady-state decode must keep its operands resident: no large
    all-gather (nothing KV-cache- or weight-sized crosses shards), no
    all-to-all, and the collective count flat in the scan length — the only
    per-step collectives are the TP partial-sum all-reduces and KB-scale
    scatter-index broadcasts. Input and output shardings of the donated
    state/ctrl are identical, so repeated calls never reshard."""
    from repro import analysis

    eng = ServeEngine(CFG, params, max_batch=8, max_len=64,
                      sampler=SamplerConfig(temperature=0.0), mesh=serve_mesh)
    try:
        decode = [hp for hp in eng.hot_paths() if hp.name == "lm.decode"]
        assert len(decode) == 1
        # budget as declared: 16 KiB gather bound, zero all-to-all, flat
        # counts across the {1, drain_steps} family
        assert decode[0].budget.max_gather_bytes == 16384
        assert {p.label for p in decode[0].programs} == {"n=1", "n=8"}
        viols = analysis.lint_hot_paths(decode)
        assert not viols, analysis.format_report(viols)

        # No inter-call resharding: run a real step and compare layouts.
        eng.submit(Request(rid=0, prompt=np.array([5, 6, 7], np.int32),
                           max_new_tokens=4))
        eng._admit()
        before = jax.tree.map(lambda l: l.sharding, eng.state)
        eng.step()
        after = jax.tree.map(lambda l: l.sharding, eng.state)
        assert before == after
    finally:
        eng.close()


# -- mid-generation snapshot/restore on the serving mesh --------------------

@needs8
def test_midgen_snapshot_restore_sharded(params, serve_mesh, tmp_path):
    """Kill-and-restore mid-generation on the (4, 2) mesh: a fresh engine
    restored from the snapshot continues token-exactly, including requests
    that were queued-but-unadmitted at snapshot time (queue persistence).
    Restore commits host arrays straight to the canonical serving layout,
    so the donated hot-loop programs accept them without resharding."""
    def fresh():
        return ServeEngine(CFG, params, max_batch=2, max_len=64,
                           drain_steps=2,
                           sampler=SamplerConfig(temperature=0.0),
                           mesh=serve_mesh)

    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([7, 8], np.int32),
               np.array([9, 2, 6, 5, 3], np.int32),
               np.array([11, 12, 13], np.int32)]
    eng = fresh()
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=6))
    early = eng.step()   # rids 0/1 mid-generation, rids 2/3 still queued
    assert len(eng.queue) == 2
    eng.snapshot(str(tmp_path), step=0)

    ref = {c.rid: c.tokens for c in eng.run()}           # the true future
    eng2 = fresh()
    eng2.restore(str(tmp_path))
    assert len(eng2.queue) == 2
    got = {c.rid: c.tokens for c in eng2.run()}
    assert got == ref
    assert set(got) | {c.rid for c in early} == {0, 1, 2, 3}


# -- shard_map bit-serial kernel --------------------------------------------

def test_bitserial_matmul_sharded_parity():
    """Cross-subarray accumulation: KW split across "model", per-shard fused
    kernels, exact int32 psum — bit-identical to the single-device kernel."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (mesh8 CI job)")
    from repro.core.packed import prepack, shard_packed
    from repro.kernels.bitserial_matmul import (
        bitserial_matmul_fused, bitserial_matmul_sharded,
    )
    from repro.launch.mesh import make_serve_mesh

    mesh = make_serve_mesh(2)
    rng = np.random.default_rng(0)
    qa = jnp.asarray(rng.integers(0, 16, size=(16, 128)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((128, 32)), jnp.float32)
    pw = prepack(w, 4)
    want = bitserial_matmul_fused(qa, pw.planes, a_bits=4, w_bits=4,
                                  interpret=True)
    pws = shard_packed(pw, mesh, axis="model", split="k")
    # split="k" distributes the packed contraction words across the axis
    assert pws.planes.sharding.spec == jax.sharding.PartitionSpec(
        None, None, "model")
    got = bitserial_matmul_sharded(qa, pws.planes, a_bits=4, w_bits=4,
                                   mesh=mesh, interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# -- always-run subprocess coverage -----------------------------------------

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, numpy as np
from repro import analysis
from repro.distributed import sharding as sh
from repro.launch.mesh import make_serve_mesh
from repro.models.lm import ModelConfig, init
from repro.serving import Request, SamplerConfig, ServeEngine

cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab=61, remat="none", dtype="float32")
params = init(cfg, jax.random.PRNGKey(0))
prompts = [np.array([3, 1, 4, 1, 5], np.int32), np.array([7, 8], np.int32),
           np.array([9, 2, 6, 5, 3], np.int32)]

def run(mesh):
    eng = ServeEngine(cfg, params, max_batch=4, max_len=32, drain_steps=4,
                      sampler=SamplerConfig(temperature=0.0), mesh=mesh)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
    return eng, {c.rid: c.tokens for c in eng.run()}

# sharded first: the mesh must stay scoped to the engine's own calls, so
# the mesh-free engine after it decodes on an untouched global state
eng, shard = run(make_serve_mesh(2))
assert sh.get_mesh() is None, "engine leaked its mesh"
_, plain = run(None)
# lint the sharded engine's decode hot path with the shared rule: the
# 16 KiB gather bound, zero all-to-all and drain-length flatness
decode = [hp for hp in eng.hot_paths() if hp.name == "lm.decode"]
viols = analysis.lint_hot_paths(decode, rules=("collective-budget",))
print(json.dumps({"parity": plain == shard,
                  "violations": [str(v) for v in viols]}))
"""


def test_sharded_serving_subprocess():
    """Tier-1 coverage without a multi-device parent: force 8 host devices
    in a child process and check parity + the collective-budget invariant."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, env=env, cwd=_REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["parity"], res
    assert not res["violations"], res
