"""int8 KV cache: quantize/fold exactness bounds + decode parity."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.models.lm import (
    ModelConfig, decode_step, forward, init, init_state, prefill,
)
from repro.models.lm.cache import quantize_kv

CFG = ModelConfig(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab=97, remat="none", dtype="float32")


def test_quantize_kv_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16)) * 3.0
    q, scale = quantize_kv(x)
    assert q.dtype == jnp.int8
    back = q.astype(jnp.float32) * scale[..., None]
    err = jnp.abs(back - x)
    assert float(err.max()) <= float(scale.max()) * 0.5 + 1e-6


def test_int8_decode_close_to_fp32():
    cfgq = dataclasses.replace(CFG, kv_quant=True)
    p = init(CFG, jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, CFG.vocab)
    logits, _ = forward(p, CFG, toks)
    st = init_state(cfgq, B, 32)
    _, st = prefill(p, cfgq, toks[:, :S - 1], st)
    ld, st = decode_step(p, cfgq, toks[:, S - 1:], st)
    ref = logits[:, -1]
    rel = float(jnp.abs(ld[:, 0] - ref).max() / (jnp.abs(ref).max() + 1e-6))
    assert rel < 0.05, rel
    # cache really is int8
    assert st["scan"][0]["k"].dtype == jnp.int8


def test_int8_cache_halves_state_bytes():
    import math

    def nbytes(state):
        return sum(math.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(state))

    # realistic head_dim so the per-(token, head) f32 scale amortizes
    cfg = dataclasses.replace(CFG, head_dim=128, dtype="bfloat16")
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    s_f = init_state(cfg, 2, 256, dtype=jnp.bfloat16)
    s_q = init_state(cfgq, 2, 256)
    assert nbytes(s_q) < 0.62 * nbytes(s_f)
