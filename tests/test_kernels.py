"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the kernel body on CPU with identical semantics)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitslice
from repro.kernels import ops, ref
from repro.kernels.bitserial_matmul import bitserial_matmul_packed


def _codes(key, shape, bits):
    return jax.random.randint(key, shape, 0, 2**bits)


@pytest.mark.parametrize("m,k,n", [
    (8, 32, 8), (16, 64, 128), (128, 128, 128), (8, 256, 128),
    (32, 96, 16), (256, 32, 256),
])
@pytest.mark.parametrize("ab,wb", [(1, 1), (2, 4), (8, 8)])
def test_bitserial_matmul_kernel_vs_oracle(m, k, n, ab, wb):
    qa = _codes(jax.random.PRNGKey(0), (m, k), ab)
    qw = _codes(jax.random.PRNGKey(1), (k, n), wb)
    got = ops.bitserial_matmul(qa, qw, a_bits=ab, w_bits=wb, interpret=True)
    want = ref.bitserial_matmul_codes_ref(qa, qw)
    assert got.dtype == jnp.int32
    assert (got == want).all()


@pytest.mark.parametrize("bm,bn,bkw", [(8, 128, 1), (16, 128, 2), (8, 256, 4)])
def test_kernel_block_shape_sweep(bm, bn, bkw):
    """Explicit BlockSpec tilings all reproduce the packed-plane oracle."""
    m, n, kw = 16, 256, 4
    ab = wb = 4
    pa = jax.random.randint(jax.random.PRNGKey(2), (ab, m, kw), 0, 2**31 - 1,
                            dtype=jnp.int32).astype(jnp.uint32)
    pw = jax.random.randint(jax.random.PRNGKey(3), (wb, n, kw), 0, 2**31 - 1,
                            dtype=jnp.int32).astype(jnp.uint32)
    got = bitserial_matmul_packed(pa, pw, a_bits=ab, w_bits=wb,
                                  bm=bm, bn=bn, bkw=bkw, interpret=True)
    want = ref.bitserial_matmul_packed_ref(pa, pw)
    assert (got == want).all()


@pytest.mark.parametrize("bn", [192, 320])
def test_kernel_non_multiple_of_128_bn(bn):
    """Regression: bn >= 128 but not a multiple of 128 used to take the
    column-chunked path and silently drop the last bn % 128 output columns
    (the shape guard's `or ... and` precedence skipped the check)."""
    m, kw = 8, 2
    ab = wb = 4
    pa = jax.random.randint(jax.random.PRNGKey(10), (ab, m, kw), 0, 2**31 - 1,
                            dtype=jnp.int32).astype(jnp.uint32)
    pw = jax.random.randint(jax.random.PRNGKey(11), (wb, bn, kw), 0, 2**31 - 1,
                            dtype=jnp.int32).astype(jnp.uint32)
    got = bitserial_matmul_packed(pa, pw, a_bits=ab, w_bits=wb,
                                  bm=m, bn=bn, bkw=kw, interpret=True)
    want = ref.bitserial_matmul_packed_ref(pa, pw)
    assert (got == want).all()
    # the trailing non-multiple columns specifically must be populated
    assert (got[:, 128:] == want[:, 128:]).all()


def test_fused_matmul_single_launch_matches_oracle():
    """bitserial_matmul with prepacked weight planes == codes oracle."""
    from repro.core.packed import prepack

    qa = _codes(jax.random.PRNGKey(12), (16, 100), 8)
    w = jax.random.normal(jax.random.PRNGKey(13), (100, 24))
    pk = prepack(w, 8)
    got = ops.bitserial_matmul(qa, a_bits=8, w_bits=8, pw=pk.planes,
                               interpret=True)
    want = ref.bitserial_matmul_codes_ref(qa, pk.codes)
    assert (got == want).all()


@pytest.mark.parametrize("m,k,bits", [(8, 32, 1), (64, 128, 8), (256, 4096, 4),
                                      (16, 96, 2)])
def test_bitplane_pack_kernel(m, k, bits):
    q = _codes(jax.random.PRNGKey(4), (m, k), bits)
    got = ops.pack_planes(q, bits, interpret=True)
    want = ref.bitplane_pack_ref(
        jnp.pad(q, ((0, 0), (0, bitslice.pad_to_lanes(k) - k))), bits)
    assert got.dtype == jnp.uint32
    assert (got == want).all()


def test_pack_unpack_roundtrip():
    q = _codes(jax.random.PRNGKey(5), (4, 100), 8)
    planes = bitslice.slice_and_pack(q, 8)
    back = sum(bitslice.unpack_bits(planes[b], 100).astype(jnp.int32) << b
               for b in range(8))
    assert (back == q).all()


def test_kernel_end_to_end_quantized_matmul():
    """The 'pallas' backend slots into the float-facing pipeline."""
    from repro.core.bitserial import quantized_matmul

    a = jax.random.normal(jax.random.PRNGKey(6), (8, 128))
    w = jax.random.normal(jax.random.PRNGKey(7), (128, 16))
    y_pallas = quantized_matmul(a, w, 8, 8, backend="pallas")
    y_ref = quantized_matmul(a, w, 8, 8, backend="int-direct")
    assert jnp.allclose(y_pallas, y_ref, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.int16, jnp.int8])
def test_kernel_input_dtypes(dtype):
    """Codes arriving in narrower integer dtypes pack identically."""
    qa = _codes(jax.random.PRNGKey(8), (8, 64), 4).astype(dtype)
    qw = _codes(jax.random.PRNGKey(9), (64, 8), 4).astype(dtype)
    got = ops.bitserial_matmul(qa.astype(jnp.int32), qw.astype(jnp.int32),
                               a_bits=4, w_bits=4, interpret=True)
    want = ref.bitserial_matmul_codes_ref(qa.astype(jnp.int32),
                                          qw.astype(jnp.int32))
    assert (got == want).all()


@pytest.mark.parametrize("bh,s,d,chunk", [
    (2, 32, 8, 8), (6, 64, 16, 16), (1, 48, 32, 16), (4, 128, 16, 32),
])
def test_wkv_chunk_kernel_vs_scan_oracle(bh, s, d, chunk):
    """Pallas chunked-WKV kernel == sequential recurrence, shape sweep."""
    from repro.kernels.rwkv_chunk import wkv_chunked

    key = jax.random.PRNGKey(bh * 1000 + s)
    r, k, v = (jax.random.normal(jax.random.fold_in(key, i), (bh, s, d)) * 0.5
               for i in range(3))
    lw = jnp.maximum(
        -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (bh, s, d)) - 2),
        -5.0)
    u = jax.random.normal(jax.random.fold_in(key, 4), (bh, d)) * 0.2
    s0 = jax.random.normal(jax.random.fold_in(key, 5), (bh, d, d)) * 0.1
    y_ref, s_ref = ref.wkv_chunked_ref(r, k, v, lw, u, s0)
    y, s_fin = wkv_chunked(r, k, v, lw, u, s0, chunk=chunk, interpret=True)
    assert jnp.abs(y - y_ref).max() / (jnp.abs(y_ref).max() + 1e-9) < 1e-4
    assert jnp.abs(s_fin - s_ref).max() < 1e-3
