"""PIM architecture simulator: reproduction of the paper's §5 endpoints.

The calibrated simulator must reproduce Table 3 / Fig. 16 by construction
(calibration), and the *sweep behaviors* (Figs. 13-15) as predictions."""
import math

import pytest

from repro.pim.area import add_on_area_mm2, chip_area_mm2
from repro.pim.baselines import (
    MODELS, WI_CONFIGS, energy_table, speedup_table,
)
from repro.pim.calibrate import (
    PAPER_CLAIMS, PAPER_ENERGY_FRACTIONS, PAPER_LATENCY_FRACTIONS,
)
from repro.pim.hierarchy import Geometry
from repro.pim.simulator import peak_gops, simulate_model


def test_resnet50_throughput_matches_table3():
    r = simulate_model("resnet50")
    assert r.fps == pytest.approx(PAPER_CLAIMS["throughput_fps"], rel=0.02)


def test_latency_breakdown_matches_fig16a():
    r = simulate_model("resnet50")
    for phase, frac in PAPER_LATENCY_FRACTIONS.items():
        assert r.latency_breakdown[phase] == pytest.approx(frac, abs=0.02), phase


def test_energy_breakdown_matches_fig16b():
    r = simulate_model("resnet50")
    for phase, frac in PAPER_ENERGY_FRACTIONS.items():
        assert r.energy_breakdown[phase] == pytest.approx(frac, abs=0.02), phase


def test_area_matches_table3():
    assert chip_area_mm2(Geometry()) == pytest.approx(
        PAPER_CLAIMS["area_mm2"], rel=0.02)
    split = add_on_area_mm2(Geometry())
    assert split["compute_units"] / sum(split.values()) == pytest.approx(0.47, abs=0.01)


@pytest.mark.parametrize("claim,key,rel", [
    ("speedup_vs_dram", "DRISA", 0.05), ("speedup_vs_stt", "STT-CiM", 0.05),
    # IMCE: its Table 3 anchor (80.6/64.5)/(21.8/128.3) = 7.35x per-area at
    # <8:8> already exceeds the §5.3 claimed 5.1x AVERAGE — internally
    # inconsistent under any monotone precision law. We pin the Table 3
    # anchor and accept the residual (see EXPERIMENTS.md discrepancies).
    ("speedup_vs_sot", "IMCE", 0.35),
    ("speedup_vs_reram", "PRIME", 0.05),
])
def test_average_speedups_match_section53(claim, key, rel):
    table = speedup_table()
    vals = [v for (m, cfg, name), v in table.items() if name == key]
    avg = sum(vals) / len(vals)
    assert avg == pytest.approx(PAPER_CLAIMS[claim], rel=rel), (key, avg)


def test_speedup_grows_with_precision():
    """§5.3: 'the improvement ... becomes increasingly evident when <W:I>
    increases' — check monotone trend vs the STT baseline on resnet50."""
    table = speedup_table()
    seq = [table[("resnet50", cfg, "STT-CiM")] for cfg in WI_CONFIGS]
    assert seq[-1] > seq[0], seq


@pytest.mark.parametrize("claim,key", [
    ("energy_vs_dram", "DRISA"), ("energy_vs_stt", "STT-CiM"),
    ("energy_vs_reram", "PRIME"),
])
def test_average_energy_ratios_match_section53(claim, key):
    table = energy_table()
    vals = [v for (m, cfg, name), v in table.items() if name == key]
    avg = sum(vals) / len(vals)
    assert avg == pytest.approx(PAPER_CLAIMS[claim], rel=0.05), (key, avg)


def test_capacity_sweep_shape_fig13a():
    """Peak perf/area rises with capacity then flattens; efficiency falls."""
    geoms = [Geometry().with_capacity(c) for c in (16, 32, 64, 128)]
    perf_per_area = [peak_gops(g) / chip_area_mm2(g) for g in geoms]
    assert perf_per_area[1] > perf_per_area[0] * 0.95
    # energy efficiency (fps/W proxy: 1/energy) decreases with capacity
    effs = [1.0 / simulate_model("resnet50", geometry=g).energy for g in geoms]
    assert effs[-1] < effs[0]


def test_bandwidth_sweep_fig13b():
    """Throughput rises with bus width (weight broadcast de-bottlenecks)."""
    fps = [simulate_model("resnet50", geometry=Geometry().with_bus(b)).fps
           for b in (32, 64, 128, 256)]
    assert fps[0] < fps[1] < fps[2]


def test_precision_scaling():
    """<2:2> must beat <8:8> in fps (bit-serial work ~ W*I plane pairs)."""
    f22 = simulate_model("resnet50", ab=2, wb=2).fps
    f88 = simulate_model("resnet50", ab=8, wb=8).fps
    f1616 = simulate_model("resnet50", ab=16, wb=16).fps
    assert f22 > f88 > f1616


def test_all_models_simulate():
    for m in MODELS:
        r = simulate_model(m)
        assert r.fps > 0 and r.energy > 0
        assert math.isfinite(r.latency)
