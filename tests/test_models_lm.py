"""Per-arch smoke tests: every assigned architecture instantiates a REDUCED
same-family config and runs one forward + one train step on CPU, asserting
output shapes and the absence of NaNs; decode parity vs the full-sequence
forward is checked per family."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import (
    decode_step, forward, init, init_state, loss_fn, param_count, prefill,
)
from repro.models.lm.model import layer_plan

B, S = 2, 24


def _inputs(cfg, key):
    if cfg.embed_inputs:
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    else:
        tokens = (jax.random.normal(key, (B, S, cfg.d_model), jnp.float32) * 0.1
                  ).astype(jnp.dtype(cfg.dtype))
    batch = {"tokens": tokens,
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.cross_attn_every:
        batch["image_embeds"] = (jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.d_model)) * 0.1).astype(
                jnp.dtype(cfg.dtype))
    return batch


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch_id):
        if arch_id not in cache:
            cfg = get_config(arch_id).model.reduced()
            params = init(cfg, jax.random.PRNGKey(0))
            cache[arch_id] = (cfg, params)
        return cache[arch_id]

    return get


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_no_nans(arch_setup, arch_id):
    cfg, params = arch_setup(arch_id)
    batch = _inputs(cfg, jax.random.PRNGKey(1))
    logits, aux = forward(params, cfg, batch["tokens"],
                          image_embeds=batch.get("image_embeds"))
    assert logits.shape == (B, S, cfg.vocab)
    assert jnp.isfinite(logits).all(), f"{arch_id}: non-finite logits"
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_decreases_nothing_nan(arch_setup, arch_id):
    cfg, params = arch_setup(arch_id)
    batch = _inputs(cfg, jax.random.PRNGKey(2))
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch, train=True))(params)
    assert jnp.isfinite(loss), f"{arch_id}: loss {loss}"
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)) ** 0.5
    assert jnp.isfinite(gnorm) and gnorm > 0, f"{arch_id}: grad norm {gnorm}"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_matches_forward(arch_setup, arch_id):
    """prefill(S-1) + decode(1) logits ~= forward(S) last-position logits.

    MoE runs with a no-drop capacity factor: token dropping legitimately
    differs between a 2S-token forward and an (S-1)+1 prefill/decode split,
    so parity is only defined for the drop-free router."""
    import dataclasses

    cfg, params = arch_setup(arch_id)
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    batch = _inputs(cfg, jax.random.PRNGKey(3))
    toks = batch["tokens"]
    img = batch.get("image_embeds")
    logits, _ = forward(params, cfg, toks, image_embeds=img)
    st = init_state(cfg, B, S + 8)
    _, st = prefill(params, cfg, toks[:, :S - 1], st, image_embeds=img)
    ld, st = decode_step(params, cfg, toks[:, S - 1:], st, image_embeds=img)
    ref = logits[:, -1]
    # bf16 scan reassociation allows small drift.
    rel = jnp.abs(ld[:, 0] - ref).max() / (jnp.abs(ref).max() + 1e-6)
    assert rel < 0.05, f"{arch_id}: decode/forward rel err {rel}"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_abstract(arch_id):
    """FULL configs build abstractly (no allocation) with sane param counts."""
    cfg = get_config(arch_id).model
    n = param_count(cfg)
    assert n > 100e6, f"{arch_id}: suspiciously small ({n})"
    unit, reps, rest = layer_plan(cfg)
    assert reps * len(unit) + len(rest) == len(cfg.blocks)


def test_layer_plan_patterns():
    cfg = get_config("recurrentgemma-9b").model
    unit, reps, rest = layer_plan(cfg)
    assert unit == ("rglru", "rglru", "local_attn") and reps == 12
    assert rest == ("rglru", "rglru")
    cfg = get_config("llama-3.2-vision-90b").model
    unit, reps, rest = layer_plan(cfg)
    assert "cross_attn" in unit and reps * len(unit) == 100


def test_long_context_applicability():
    for arch_id in ARCH_IDS:
        arch = get_config(arch_id)
        shapes = arch.applicable_shapes()
        if arch_id in ("recurrentgemma-9b", "rwkv6-3b"):
            assert not isinstance(shapes["long_500k"], str), arch_id
        else:
            assert isinstance(shapes["long_500k"], str), arch_id
