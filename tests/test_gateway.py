"""Overload-safe gateway (DESIGN.md §8): bounded weighted-fair admission,
deadline expiry + mid-generation cancellation, load shedding with
retry-after, degradation-ladder levers and reversibility, telemetry rings,
and token parity vs the bare engine."""
import asyncio
import time
import types

import jax
import numpy as np
import pytest

from repro.models.lm import ModelConfig, init
from repro.serving import (DeadlineExceeded, Gateway, GatewayConfig, Request,
                           Ring, SamplerConfig, ServeEngine, ShedError,
                           VisionEngine, VisionRequest)
from repro.serving.gateway import _FairQueues, _Handle

CFG = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                  vocab=51, remat="none", dtype="float32")


@pytest.fixture(scope="module")
def params():
    return init(CFG, jax.random.PRNGKey(0))


def _engine(params, max_batch=2, max_len=64, **kw):
    return ServeEngine(CFG, params, max_batch=max_batch, max_len=max_len,
                       sampler=SamplerConfig(temperature=0.0), **kw)


def _prompts(n, rng=None, lo=2, hi=9):
    rng = rng or np.random.default_rng(0)
    return [rng.integers(0, CFG.vocab, size=int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


# -- fair admission (unit) ---------------------------------------------------

def _fake_handle(tenant, rid=0):
    return _Handle(loop=None, rid=rid, tenant=tenant, kind="lm",
                   payload=None, deadline_t=None)


def test_stride_scheduling_matches_weights():
    """Weights 2:1 under saturation admit exactly 2:1 (stride scheduling)."""
    cfg = GatewayConfig(queue_depth=16, tenant_weights={"a": 2.0, "b": 1.0})
    fq = _FairQueues(cfg)
    for i in range(12):
        fq.push(_fake_handle("a", i))
        fq.push(_fake_handle("b", 100 + i))
    order = [fq.pop_next(0.0).tenant for _ in range(9)]
    assert order.count("a") == 6 and order.count("b") == 3, order
    # An idle tenant's share redistributes: drain b, a still admits.
    while fq.depth("b"):
        fq.pop_next(0.0)
    assert all(fq.pop_next(0.0).tenant == "a" for _ in range(fq.depth("a")))


def test_fair_queue_new_tenant_no_catchup():
    """A late-arriving tenant starts at the current min pass — it neither
    starves the incumbents nor claims retroactive catch-up credit."""
    fq = _FairQueues(GatewayConfig(queue_depth=16))
    for i in range(8):
        fq.push(_fake_handle("a", i))
    for _ in range(4):
        fq.pop_next(0.0)
    for i in range(8):
        fq.push(_fake_handle("late", 100 + i))
    order = [fq.pop_next(0.0).tenant for _ in range(4)]
    # Equal weights from here on: strict alternation, not a "late" monopoly.
    assert sorted(order.count(t) for t in ("a", "late")) == [2, 2], order


# -- shedding + bounded queues ----------------------------------------------

def test_full_queue_sheds_with_retry_after(params):
    async def main():
        eng = _engine(params, max_batch=1)
        gw = Gateway(lm=eng, cfg=GatewayConfig(queue_depth=2))
        gw.start()
        prompts = _prompts(16)
        streams, sheds = [], []
        # Flood without yielding: the worker can admit at most max_batch=1
        # concurrently, so the depth-2 tenant queue must overflow.
        for rid, p in enumerate(prompts):
            try:
                streams.append(await gw.submit_lm(p, max_new_tokens=4,
                                                  rid=rid))
            except ShedError as e:
                sheds.append(e)
        assert sheds, "expected at least one shed from a depth-2 queue"
        assert all(e.retry_after_s > 0 for e in sheds)
        assert all(e.reason == "queue_full" for e in sheds)
        outs = await asyncio.gather(*[s.result() for s in streams])
        await gw.drain(timeout=60)
        st = gw.stats()
        gw.stop()
        # Bounded by construction: the recorded high-water mark respects it.
        assert st["queue"]["max_depth"] <= st["queue"]["bound"]
        assert st["shed_rate"] > 0
        assert all(len(o) == 4 for o in outs)

    asyncio.run(main())


# -- token parity ------------------------------------------------------------

def test_gateway_token_parity_vs_bare_engine(params):
    """The gateway adds zero numerics: streamed tokens are bit-identical to
    the bare engine run with the same prompts (greedy)."""
    prompts = _prompts(6)
    eng = _engine(params)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
    want = {c.rid: c.tokens for c in eng.run()}

    async def main():
        gw = Gateway(lm=_engine(params), cfg=GatewayConfig(queue_depth=8))
        gw.start()
        streams = [await gw.submit_lm(p, max_new_tokens=5, rid=rid)
                   for rid, p in enumerate(prompts)]
        outs = await asyncio.gather(*[s.result() for s in streams])
        await gw.drain(timeout=60)
        gw.stop()
        return {s.rid: o for s, o in zip(streams, outs)}

    got = asyncio.run(main())
    assert got == want


# -- deadlines ---------------------------------------------------------------

def test_deadline_expires_while_queued(params):
    async def main():
        eng = _engine(params, max_batch=1)
        gw = Gateway(lm=eng, cfg=GatewayConfig(queue_depth=8))
        gw.start()
        # Occupy the only slot with a long generation, then queue a request
        # whose deadline cannot survive the wait.
        long_s = await gw.submit_lm(_prompts(1)[0], max_new_tokens=40,
                                    rid=0)
        doomed = await gw.submit_lm(_prompts(1)[0], max_new_tokens=4,
                                    rid=1, deadline_ms=1.0)
        with pytest.raises(DeadlineExceeded):
            await doomed.result()
        assert doomed.status == "expired"
        out = await long_s.result()
        assert len(out) == 40, "survivor must be unaffected by the expiry"
        await gw.drain(timeout=60)
        gw.stop()

    asyncio.run(main())


def test_deadline_cancels_mid_generation_and_frees_slot(params):
    async def main():
        eng = _engine(params, max_batch=1, drain_steps=1)
        gw = Gateway(lm=eng, cfg=GatewayConfig(queue_depth=8))
        gw.start()
        s = await gw.submit_lm(_prompts(1)[0], max_new_tokens=55,
                               rid=0, deadline_ms=150.0)
        with pytest.raises(DeadlineExceeded):
            await s.result()
        assert s.status == "expired"
        assert s.tokens, "some tokens must have streamed before expiry"
        # The slot frees at the next token boundary: a follow-up request
        # admits and completes, token-identical to a fresh engine.
        follow = await gw.submit_lm(np.array([3, 1, 4], np.int32),
                                    max_new_tokens=6, rid=1)
        got = await follow.result()
        await gw.drain(timeout=60)
        gw.stop()
        assert all(r is None for r in eng.slot_req)
        return got

    got = asyncio.run(main())
    fresh = _engine(params, max_batch=1)
    fresh.submit(Request(rid=0, prompt=np.array([3, 1, 4], np.int32),
                         max_new_tokens=6))
    assert got == fresh.run()[0].tokens


def test_submit_lm_validates_on_caller_thread(params):
    async def main():
        gw = Gateway(lm=_engine(params, max_len=32),
                     cfg=GatewayConfig(queue_depth=4))
        gw.start()
        with pytest.raises(ValueError, match="empty prompt"):
            await gw.submit_lm(np.zeros(0, np.int32), max_new_tokens=4)
        with pytest.raises(ValueError, match="exceeds the decode grid"):
            await gw.submit_lm(np.arange(30, dtype=np.int32) % CFG.vocab,
                               max_new_tokens=8)
        gw.stop()

    asyncio.run(main())


# -- degradation ladder ------------------------------------------------------

def test_ladder_tier1_engages_and_reverses(params):
    async def main():
        eng = _engine(params, max_batch=1, drain_steps=8)
        gw = Gateway(lm=eng, cfg=GatewayConfig(
            queue_depth=4, tier_hold_s=0.03, overload_enter=0.5,
            overload_exit=0.25, degraded_drain_steps=1))
        gw.start()
        tasks, t0 = [], time.monotonic()
        saw_tier = 0
        while time.monotonic() - t0 < 4.0:
            try:
                s = await gw.submit_lm(_prompts(1)[0], max_new_tokens=16)
                tasks.append(asyncio.ensure_future(s.result()))
            except ShedError:
                await asyncio.sleep(0.01)
            saw_tier = max(saw_tier, gw.stats()["tier"])
            if saw_tier >= 1 and eng.drain_steps == 1:
                break
        assert saw_tier >= 1, "sustained overload never escalated the ladder"
        assert eng.drain_steps == 1, "tier-1 lever did not shrink drain_steps"
        # Load drops: the ladder walks back and restores the lever.
        await asyncio.gather(*tasks, return_exceptions=True)
        await gw.drain(timeout=60)
        t0 = time.monotonic()
        while gw.stats()["tier"] > 0 and time.monotonic() - t0 < 5.0:
            await asyncio.sleep(0.02)
        st = gw.stats()
        gw.stop()
        assert st["tier"] == 0, "ladder did not de-escalate after drain"
        assert eng.drain_steps == 8, "tier-1 lever was not reversed"
        assert any(e.get("tier") == 1 for e in st["events"]), st["events"]

    asyncio.run(main())


def test_tier2_precision_redeploy_reversible(params):
    """Tier 2 re-deploys the LM engine on a cheaper path via the PR 5
    re-prepack machinery and reverses on de-escalation (lever unit test —
    the ladder's timing is exercised by the tier-1 test)."""
    from repro.core import PIMQuantConfig
    import dataclasses as dc

    cfg = dc.replace(CFG, pim=PIMQuantConfig(w_bits=4, a_bits=4,
                                             backend="int-direct"))
    pim_params = init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, pim_params, max_batch=2, max_len=64,
                      sampler=SamplerConfig(temperature=0.0),
                      keep_masters=True)
    gw = Gateway(lm=eng, cfg=GatewayConfig(degrade_precision=True))
    assert eng.cfg.pim.enabled
    gw._set_tier(2, "test")
    assert not eng.cfg.pim.enabled, "tier 2 must re-deploy off the PIM path"
    gw._set_tier(1, "test")
    assert eng.cfg.pim.enabled, "de-escalation must restore the precision"
    # The re-deployed engine still serves correctly end to end.
    eng.submit(Request(rid=0, prompt=np.array([3, 1, 4], np.int32),
                       max_new_tokens=4))
    assert len(eng.run()[0].tokens) == 4


def test_tier3_sheds_lowest_priority_tenant(params):
    async def main():
        eng = _engine(params, max_batch=1)
        # tier_hold_s=60: pin the ladder so only the explicit _set_tier
        # calls below move it (the load here is far below overload_enter).
        gw = Gateway(lm=eng, cfg=GatewayConfig(
            queue_depth=8, tier_hold_s=60.0,
            tenant_priority={"gold": 1, "bronze": 0}))
        gw.start()
        # Park one doomed bronze request in the queue behind a long one.
        blocker = await gw.submit_lm(_prompts(1)[0], max_new_tokens=30,
                                     tenant="gold")
        parked = await gw.submit_lm(_prompts(1)[0], max_new_tokens=4,
                                    tenant="bronze")
        parked_task = asyncio.ensure_future(parked.result())
        await asyncio.sleep(0)
        gw._set_tier(3, "test")
        with pytest.raises(ShedError):
            await parked_task
        with pytest.raises(ShedError):   # new bronze submissions rejected
            await gw.submit_lm(_prompts(1)[0], max_new_tokens=4,
                               tenant="bronze")
        gold = await gw.submit_lm(_prompts(1)[0], max_new_tokens=4,
                                  tenant="gold")   # gold still admitted
        assert len(await gold.result()) == 4
        gw._set_tier(0, "test")
        bronze = await gw.submit_lm(_prompts(1)[0], max_new_tokens=4,
                                    tenant="bronze")
        assert len(await bronze.result()) == 4, "tier-3 shed must reverse"
        await blocker.result()
        await gw.drain(timeout=60)
        gw.stop()

    asyncio.run(main())


# -- vision path -------------------------------------------------------------

def _tiny_cnn():
    from repro.models.cnn import layers as L

    def cnn_init(key, image=16, num_classes=10):
        k1, k2 = jax.random.split(key)
        return {"c1": L.init_conv(k1, 3, 3, 8),
                "head": L.init_fc(k2, 8, num_classes)}

    def cnn_apply(params, x, cfg=None, train=False):
        x = L.conv_block(params["c1"], x, stride=2, padding=1, cfg=cfg,
                         train=train)
        x = L.avg_pool_global(x)
        return L.fc_block(params["head"], x, cfg=cfg, relu=False,
                          train=train)

    module = types.SimpleNamespace(init=cnn_init, apply=cnn_apply)
    return module, cnn_init(jax.random.PRNGKey(0))


def test_vision_gateway_roundtrip_matches_engine():
    module, vparams = _tiny_cnn()
    rng = np.random.default_rng(0)
    imgs = rng.standard_normal((4, 16, 16, 3)).astype(np.float32)

    eng = VisionEngine({"tiny": (module, vparams)}, backend="int-direct",
                       max_batch=4)
    for rid in range(4):
        eng.submit(VisionRequest(rid=rid, image=imgs[rid], model="tiny",
                                 precision="<4:4>"))
    want = {c.rid: (c.top1, c.logits) for c in eng.run()}

    async def main():
        gw = Gateway(vision=VisionEngine({"tiny": (module, vparams)},
                                         backend="int-direct", max_batch=4),
                     cfg=GatewayConfig(queue_depth=8))
        gw.start()
        tickets = [await gw.submit_vision(imgs[rid], model="tiny",
                                          precision="<4:4>", rid=rid)
                   for rid in range(4)]
        outs = await asyncio.gather(*[t.result() for t in tickets])
        await gw.drain(timeout=60)
        st = gw.stats()
        gw.stop()
        assert st["ttft_ms"]["p50"] is not None
        return {c.rid: (c.top1, c.logits) for c in outs}

    got = asyncio.run(main())
    assert got.keys() == want.keys()
    for rid in want:
        assert got[rid][0] == want[rid][0]
        np.testing.assert_array_equal(got[rid][1], want[rid][1])


def test_vision_deadline_expires_queued():
    module, vparams = _tiny_cnn()
    img = np.zeros((16, 16, 3), np.float32)

    async def main():
        gw = Gateway(vision=VisionEngine({"tiny": (module, vparams)},
                                         max_batch=2),
                     cfg=GatewayConfig(queue_depth=8))
        gw.start()
        # Deadline already burned at submission time.
        t = await gw.submit_vision(img, model="tiny", precision=None,
                                   deadline_ms=0.0)
        with pytest.raises(DeadlineExceeded):
            await t.result()
        ok = await gw.submit_vision(img, model="tiny", precision=None)
        c = await ok.result()
        assert c.logits.shape == (10,)
        await gw.drain(timeout=60)
        gw.stop()

    asyncio.run(main())


# -- telemetry ---------------------------------------------------------------

def test_ring_is_fixed_size():
    r = Ring(16)
    for i in range(1000):
        r.push(float(i))
    assert len(r) == 16
    assert r.values().min() == 984.0   # only the newest window survives
    p = r.percentiles()
    assert set(p) == {"p50", "p95", "p99"} and p["p50"] >= 984.0
    assert Ring(8).percentiles() == {"p50": None, "p95": None, "p99": None}


def test_stats_snapshot_shape(params):
    async def main():
        gw = Gateway(lm=_engine(params), cfg=GatewayConfig(queue_depth=4))
        gw.start()
        s = await gw.submit_lm(_prompts(1)[0], max_new_tokens=4,
                               tenant="acme")
        await s.result()
        await gw.drain(timeout=60)
        st = gw.stats()
        gw.stop()
        return st

    st = asyncio.run(main())
    for key in ("tier", "queue", "ttft_ms", "ttft_admit_ms", "tpot_ms",
                "tok_s", "shed", "shed_rate", "goodput_tok_s_by_tenant",
                "events", "errors", "lm_health"):
        assert key in st, key
    assert st["queue"]["bound"] > 0
    assert "acme" in st["goodput_tok_s_by_tenant"]
    assert st["shed_rate"] == 0.0
    assert st["errors"] == []
