"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional dev dependency (installed in CI); the whole
module skips cleanly when it is absent so tier-1 collection never breaks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bitslice
from repro.core.bitserial import int_matmul_direct, int_matmul_popcount
from repro.core.quantize import calibrate_minmax, dequantize, quantize
from repro.models.lm.config import ModelConfig
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import OptimizerConfig, schedule


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 8), k=st.integers(1, 64), n=st.integers(1, 8),
       ab=st.integers(1, 8), wb=st.integers(1, 8), seed=st.integers(0, 2**16))
def test_eq1_identity(m, k, n, ab, wb, seed):
    """Paper Eq. 1: the bit-plane decomposition is an exact identity."""
    key = jax.random.PRNGKey(seed)
    qa = jax.random.randint(key, (m, k), 0, 2**ab)
    qw = jax.random.randint(jax.random.fold_in(key, 1), (k, n), 0, 2**wb)
    assert (int_matmul_popcount(qa, qw, ab, wb) == int_matmul_direct(qa, qw)).all()


@settings(max_examples=20, deadline=None)
@given(bits=st.integers(1, 12), k=st.integers(1, 200))
def test_pack_is_lossless(bits, k):
    q = jax.random.randint(jax.random.PRNGKey(k), (3, k), 0, 2**bits)
    planes = bitslice.slice_and_pack(q, bits)
    assert planes.shape == (bits, 3, bitslice.pad_to_lanes(k) // 32)
    back = sum(bitslice.unpack_bits(planes[b], k).astype(jnp.int32) << b
               for b in range(bits))
    assert (back == q).all()


@settings(max_examples=25, deadline=None)
@given(
    bits=st.integers(1, 8),
    lo=st.floats(-100, 99, allow_nan=False),
    span=st.floats(0.01, 200, allow_nan=False),
)
def test_quantize_roundtrip_bound(bits, lo, span):
    """|dequant(quant(x)) - x| <= scale/2 for x within the calibration range.

    Tolerance includes an f32-cancellation allowance proportional to the
    offset magnitude ((x - qmin) loses bits when span << |lo|)."""
    from repro.core.quantize import dequantize as dq

    x = jnp.linspace(lo, lo + span, 97)
    qp = calibrate_minmax(x, bits)
    err = jnp.abs(dq(quantize(x, qp), qp) - x)
    tol = float(qp.scale) / 2 + 1e-5 + 2e-5 * abs(lo)
    assert float(err.max()) <= tol


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(1, 8), lo=st.floats(-1e3, 1e3, allow_nan=False),
       span=st.floats(1e-3, 1e3))
def test_quantize_monotonic(bits, lo, span):
    """Eq. 2 preserves ordering (monotone non-decreasing codes).

    Spans below f32 resolution at the offset magnitude are cancellation
    territory (x - qmin loses all signal) — outside Eq. 2's domain."""
    from hypothesis import assume

    assume(span > abs(lo) * 1e-4 + 1e-3)
    x = jnp.linspace(lo, lo + span, 64)
    qp = calibrate_minmax(x, bits)
    q = quantize(x, qp)
    assert (jnp.diff(q) >= 0).all()
    err = jnp.abs(dequantize(q, qp) - x).max()
    assert float(err) <= float(qp.scale) / 2 + 1e-4 * max(1.0, abs(lo) + span)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), step=st.integers(0, 500))
def test_data_determinism(seed, step):
    """(seed, step) fully determines batch content; host slices tile it."""
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=4, seed=seed)
    src = SyntheticLM(cfg)
    b1, b2 = src.batch(step), src.batch(step)
    assert (b1["tokens"] == b2["tokens"]).all()
    sl0 = src.host_slice(step, 0, 2)
    sl1 = src.host_slice(step, 1, 2)
    assert (np.concatenate([sl0["tokens"], sl1["tokens"]]) == b1["tokens"]).all()
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


@settings(max_examples=20, deadline=None)
@given(warm=st.integers(1, 50), total=st.integers(60, 500),
       step=st.integers(0, 600))
def test_lr_schedule_bounds(warm, total, step):
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=warm, total_steps=total)
    lr = float(schedule(cfg, jnp.asarray(step)))
    assert 0.0 <= lr <= cfg.lr + 1e-9
    if step >= total:
        assert lr == pytest.approx(cfg.lr * cfg.min_lr_frac, rel=1e-3)


@settings(max_examples=15, deadline=None)
@given(n_layers=st.integers(1, 12), every=st.integers(0, 4))
def test_block_schedule_invariants(n_layers, every):
    cfg = ModelConfig(n_layers=n_layers, cross_attn_every=every,
                      n_image_tokens=8 if every else 0)
    blocks = cfg.blocks
    assert len(blocks) == n_layers
    if every:
        # no two adjacent cross-attn layers
        for a, b in zip(blocks, blocks[1:]):
            assert not (a == b == "cross_attn")


@settings(max_examples=15, deadline=None)
@given(bits=st.integers(2, 8), seed=st.integers(0, 100))
def test_compressed_psum_errorbound(bits, seed):
    """int-k compression error is bounded by the quantization step."""
    from repro.distributed.collectives import compress_decompress

    g = jax.random.normal(jax.random.PRNGKey(seed), (128,))
    err0 = jnp.zeros_like(g)
    g_hat, err = compress_decompress(g, err0, bits)
    step = float(jnp.abs(g).max()) / (2 ** (bits - 1) - 1)
    assert float(jnp.abs(g_hat - g).max()) <= step * 0.5 + 1e-6
    # error feedback: residual equals exactly what was lost
    assert jnp.allclose(g_hat + err, g, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 300), n=st.integers(1, 300), kw=st.integers(1, 64),
       ab=st.integers(1, 8), wb=st.integers(1, 8),
       bm=st.one_of(st.none(), st.integers(1, 512)),
       bn=st.one_of(st.none(), st.integers(1, 512)),
       bkw=st.one_of(st.none(), st.integers(1, 512)))
def test_autotune_tile_requests_always_legal(m, n, kw, ab, wb, bm, bn, bkw):
    """Any tile request — autotuner decision or caller whim — legalizes to
    blocks the Pallas kernel's ``_check_blocks`` accepts: the tuned path
    can never produce an illegal BlockSpec."""
    from repro.kernels.bitserial_matmul import _check_blocks
    from repro.kernels.ops import matmul_tiles

    lb, ln, lk = matmul_tiles(m, n, kw, ab, wb, bm, bn, bkw)
    _check_blocks(m, n, kw, lb, ln, lk)    # must not raise
    assert 1 <= lb <= m and 1 <= ln <= n and 1 <= lk <= kw


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 64), k=st.integers(1, 512), n=st.integers(1, 256),
       ab=st.sampled_from([2, 4, 8]), wb=st.sampled_from([2, 4, 8]))
def test_autotune_decision_deterministic(m, k, n, ab, wb):
    """decide_gemm is a pure function of (shape, precision, candidate set):
    rerunning it — fresh or through a warm cache — returns the same pick."""
    from repro.pim import autotune as at

    cache = at.TuningCache(None)
    d1 = at.decide_gemm(m, k, n, ab, wb, cache=cache, hlo_tiebreak=False)
    d2 = at.decide_gemm(m, k, n, ab, wb, cache=cache, hlo_tiebreak=False)
    d3 = at.decide_gemm(m, k, n, ab, wb, hlo_tiebreak=False)
    assert d1 == d2 == d3
    assert d1.backend in at.XLA_BACKENDS
