"""Paper CNN stack: forward shapes, quantized-vs-fp32 agreement, spec tables."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import PIMQuantConfig
from repro.models.cnn import alexnet, resnet, vgg
from repro.models.cnn.specs import model_specs, total_macs

IMG = 64  # reduced resolution for CPU (AlexNet's stride-4 stem needs >= 64)


@pytest.mark.parametrize("mod", [alexnet, resnet, vgg])
def test_forward_shapes(mod):
    params = mod.init(jax.random.PRNGKey(0), image=IMG, num_classes=10)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, IMG, IMG, 3))
    y = mod.apply(params, x)
    assert y.shape == (2, 10)
    assert jnp.isfinite(y).all()


@pytest.mark.parametrize("mod", [alexnet, resnet])
def test_pim_quantized_forward_agrees_at_8bit(mod):
    params = mod.init(jax.random.PRNGKey(0), image=IMG, num_classes=10)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, IMG, IMG, 3))
    ref = mod.apply(params, x, cfg=None)
    q = mod.apply(params, x, cfg=PIMQuantConfig(w_bits=8, a_bits=8,
                                                backend="int-direct"))
    assert jnp.isfinite(q).all()
    # 8-bit quantization should preserve top-1 on random nets most of the time
    agree = (q.argmax(-1) == ref.argmax(-1)).mean()
    assert agree >= 0.5


def test_qat_backward_flows():
    params = alexnet.init(jax.random.PRNGKey(0), image=IMG, num_classes=10)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, IMG, IMG, 3))
    cfg = PIMQuantConfig(w_bits=4, a_bits=4)

    def loss(p):
        return alexnet.apply(p, x, cfg=cfg, train=True).sum()

    g = jax.grad(loss)(params)
    gnorm = sum(jnp.abs(l).sum() for l in jax.tree.leaves(g))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("model,macs_ref", [
    ("alexnet", 1.1e9), ("vgg19", 19.6e9), ("resnet50", 4.1e9),
])
def test_spec_tables_match_published_macs(model, macs_ref):
    """GEMM spec tables reproduce the published MAC counts at 224px."""
    specs = model_specs(model, batch=1, image=224)
    macs = total_macs(specs)
    assert macs == pytest.approx(macs_ref, rel=0.12), macs
