"""Roofline machinery: the trip-count-aware HLO walker against known-cost
programs, collective parsing, and in-place slice accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis, hlo_cost


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplication():
    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    c = hlo_cost.analyze(_compiled_text(scanned, x, ws))
    assert c.flops == pytest.approx(7 * 2 * 128**3, rel=0.01)


def test_nested_scan_multiplies_both_levels():
    def nested(x, ws):
        def outer(c, _):
            return jax.lax.scan(lambda d, w: (d @ w, None), c, ws)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = hlo_cost.analyze(_compiled_text(nested, x, ws))
    assert c.flops == pytest.approx(15 * 2 * 64**3, rel=0.02)


def test_dus_counts_update_not_buffer():
    """Scan accumulating into a big buffer: bytes ~ S*slice, not S*buffer."""
    def accum(ys, xs):
        def body(c, i):
            return c, xs[i] * 2.0
        _, out = jax.lax.scan(body, 0.0, jnp.arange(64))
        return out

    xs = jax.ShapeDtypeStruct((64, 1024), jnp.float32)
    c = hlo_cost.analyze(_compiled_text(accum, jnp.zeros(()), xs))
    slice_bytes = 1024 * 4
    # read slice + compute + write slice per step (small constant factor)
    assert c.bytes < 64 * slice_bytes * 8, c.bytes
    assert c.bytes > 64 * slice_bytes, c.bytes


def test_dot_flops_formula():
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    c = hlo_cost.analyze(_compiled_text(lambda a, b: a @ b, a, b))
    assert c.flops == pytest.approx(2 * 32 * 64 * 16, rel=0.01)


def test_shape_bytes_parser():
    assert analysis.shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert analysis.shape_bytes("(f32[4], s32[2,2])") == 16 + 16
    assert analysis.shape_bytes("pred[16]{0}") == 16


def test_roofline_terms_and_bottleneck():
    r = analysis.Roofline(flops_per_chip=197e12, hbm_bytes_per_chip=819e9,
                          wire_bytes_per_chip=0.0, chips=2,
                          model_flops=2 * 197e12)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.bottleneck in ("compute", "memory")
    assert r.useful_flops_fraction == pytest.approx(1.0)


def test_collective_ring_factors():
    hlo = """
ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %cp = f32[1024]{0} collective-permute(%ar), source_target_pairs={{0,1},{1,0}}
}
"""
    c = hlo_cost.analyze(hlo)
    b = 1024 * 4
    assert c.coll_counts["all-reduce"] == 1
    assert c.coll_counts["collective-permute"] == 1
    assert c.wire_bytes == pytest.approx(2 * b * 3 / 4 + b)
