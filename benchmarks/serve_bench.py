"""Serving throughput benchmark: seed engine hot loop vs the fused one,
plus a device-count scaling sweep over the serving mesh.

``_LegacyEngine`` reproduces the pre-overhaul ``ServeEngine`` faithfully:
unjitted batch-1 prefill + host-side graft (rebuilds every leaf of the full
(max_batch, max_len) grid with ``at[].set`` per admission), a jitted decode
that transfers the full (B, vocab) logits to host every token, eager
host-side sampling keyed by ``PRNGKey(slot_pos.sum())``, and a per-step
host->device upload of the position array. The current engine replaces all
of that with donated in-jit programs (see ``repro/serving/engine.py`` and
DESIGN.md §4); this module quantifies the difference.

Measured per batch size, same prompt-length mix on both paths:
  * ``gen_tok_s``  — generated tokens/sec over a full continuous-batching
    run on a warm engine (compile caches populated by a first run);
  * ``ttft_ms``    — time-to-first-token for one admission into a warm
    engine (prompt prefill + first sampled token).

``serve_device_scaling`` sweeps the mesh-sharded engine across forced
host-device counts (each cell is a subprocess: XLA fixes the device count
at backend init), recording decode tokens/sec per (data × model) mesh —
the paper's chips × banks mapping (DESIGN.md §5). On a CPU host the forced
devices share the same cores, so this tracks the *mechanism* (collective
overhead, layout stability), not real speedup; on a TPU slice the same
rows measure actual scaling.

``benchmarks.run --only serve`` renders the tables and writes
``BENCH_serving.json`` at the repo root; ``--smoke`` shrinks the model and
token counts to CI scale (the artifact shape is identical).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import (
    ModelConfig, decode_step, init, init_state, prefill, prepack_params,
)
from repro.serving import Request, SamplerConfig, ServeEngine
from repro.serving.sampler import sample


class _LegacyEngine:
    """The seed ``ServeEngine`` hot loop, kept verbatim as the baseline."""

    def __init__(self, cfg, params, max_batch=8, max_len=512, sampler=None):
        self.cfg = cfg
        self.params = prepack_params(params, cfg.pim)
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampler = sampler or SamplerConfig()
        self.state = init_state(cfg, max_batch, max_len)
        self.slot_req = [None] * max_batch
        self.slot_remaining = np.zeros(max_batch, np.int32)
        self.slot_last_tok = np.zeros(max_batch, np.int32)
        self.queue = []
        self.done = []
        self.slot_pos = np.zeros(max_batch, np.int32)
        self._decode = jax.jit(partial(self._decode_impl, cfg))

    @staticmethod
    def _decode_impl(cfg, params, tokens, state):
        return decode_step(params, cfg, tokens, state)

    def submit(self, req):
        self.queue.append(req)

    def _admit(self):
        for slot in [i for i, r in enumerate(self.slot_req) if r is None]:
            if not self.queue:
                break
            req = self.queue.pop(0)
            L = len(req.prompt)
            tokens = jnp.asarray(req.prompt, jnp.int32)[None]
            s1 = init_state(self.cfg, 1, self.max_len)
            logits, s1 = prefill(self.params, self.cfg, tokens, s1)
            self._graft(s1, slot)
            nxt = int(sample(logits[:, -1], self.sampler,
                             jax.random.PRNGKey(req.rid))[0])
            self.slot_req[slot] = req
            self.slot_remaining[slot] = req.max_new_tokens - 1
            self.slot_last_tok[slot] = nxt
            self.slot_pos[slot] = L

    def _graft(self, s1, slot):
        def graft_leaf(big, small):
            for ax in range(min(big.ndim, 2)):
                if big.shape[ax] == self.max_batch and small.shape[ax] == 1:
                    idx = (slice(None),) * ax + (slot,)
                    src = (slice(None),) * ax + (0,)
                    return big.at[idx].set(small[src])
            return big

        new_scan = [jax.tree.map(graft_leaf, bl, sl)
                    for bl, sl in zip(self.state["scan"], s1["scan"])]
        new_rest = [jax.tree.map(graft_leaf, bl, sl)
                    for bl, sl in zip(self.state["rest"], s1["rest"])]
        self.state = dict(self.state, scan=new_scan, rest=new_rest)

    def step(self):
        self._admit()
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return self._drain_done()
        toks = jnp.asarray(self.slot_last_tok, jnp.int32)[:, None]
        self.state["length"] = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.state = self._decode(self.params, toks, self.state)
        nxt = np.asarray(sample(logits[:, 0], self.sampler, jax.random.PRNGKey(
            int(self.slot_pos.sum()))))
        for i in live:
            req = self.slot_req[i]
            tok = int(nxt[i])
            if not hasattr(req, "_out"):
                req._out = [int(self.slot_last_tok[i])]
            req._out.append(tok)
            self.slot_last_tok[i] = tok
            self.slot_pos[i] += 1
            self.slot_remaining[i] -= 1
            if tok == req.eos_id or self.slot_remaining[i] <= 0:
                self.done.append((req.rid, req._out))
                self.slot_req[i] = None
        return self._drain_done()

    def _drain_done(self):
        out, self.done = self.done, []
        return out

    def run(self, max_steps=10_000):
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.queue and all(r is None for r in self.slot_req):
                break
        return out


def _workload(batch, vocab, max_new, rng):
    lens = [5, 9, 12, 17, 23, 28, 33, 40]
    reqs = []
    for rid in range(batch):
        L = lens[rid % len(lens)]
        reqs.append(Request(rid=rid, prompt=rng.integers(
            0, vocab, size=L).astype(np.int32), max_new_tokens=max_new))
    return reqs


def _measure(eng, make_reqs, ttft_prompt):
    """Warm run (compiles), then timed admission + steady-state decode.

    Returns (gen_tok_s, decode_tok_s, ttft_s): overall generated tokens/sec
    including admissions, decode-only tokens/sec with all slots admitted
    (the steady-state rate), and time-to-first-token for one warm
    admission."""
    for r in make_reqs():
        eng.submit(r)
    eng.run()
    reqs = make_reqs()
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng._admit()                       # per-slot prefill + first tokens
    t_admit = time.perf_counter() - t0
    t0 = time.perf_counter()
    done = eng.run()
    t_dec = time.perf_counter() - t0
    n_tok = sum(len(t[1] if isinstance(t, tuple) else t.tokens) for t in done)
    t0 = time.perf_counter()
    eng.submit(Request(rid=10_000, prompt=ttft_prompt, max_new_tokens=2))
    eng._admit()                       # prefill + first sampled token
    ttft = time.perf_counter() - t0
    eng.run()                          # drain the probe request
    return (n_tok / (t_admit + t_dec),
            (n_tok - len(reqs)) / t_dec,   # first tokens fell in admission
            ttft)


def _scaling_cfg(smoke: bool):
    """Model/workload for the device sweep. Head and hidden dims divide the
    2-way model axis so the TP split is clean at every device count."""
    if smoke:
        cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=256, remat="none", dtype="float32")
        return cfg, 8, 64
    cfg = ModelConfig(n_layers=3, d_model=128, n_heads=4, n_kv_heads=2,
                      d_ff=256, vocab=2048, remat="none", dtype="float32")
    return cfg, 32, 128


_SCALE_SCRIPT = r"""
import sys
n, model_par, smoke = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3])
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d" % n
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
from functools import partial
import jax
import numpy as np
from benchmarks.serve_bench import _measure, _scaling_cfg, _workload
from repro.launch.mesh import make_serve_mesh
from repro.models.lm import init
from repro.serving import SamplerConfig, ServeEngine

cfg, max_new, max_len = _scaling_cfg(bool(smoke))
params = init(cfg, jax.random.PRNGKey(0))
mesh = make_serve_mesh(model_par) if n > 1 else None
eng = ServeEngine(cfg, params, max_batch=8, max_len=max_len,
                  sampler=SamplerConfig(temperature=0.0), mesh=mesh)
rng = np.random.default_rng(0)
make_reqs = partial(_workload, 8, cfg.vocab, max_new, rng)
ttft_prompt = (np.arange(1, 6, dtype=np.int32) % cfg.vocab).astype(np.int32)
gen, dec, ttft = _measure(eng, make_reqs, ttft_prompt)
# The mechanism gate: textual collective counts flat across the decode
# drain family (n=1 vs n=drain_steps) proves every collective sits outside
# the scan body — the property that survives on real accelerators, unlike
# CPU-cell speedup (see serve_device_scaling's rationale).
from repro.analysis import hlo
hp = next(h for h in eng.hot_paths() if h.name.startswith("lm.decode"))
counts = [hlo.collective_counts(p.compiled_text()) for p in hp.programs]
print(json.dumps({
    "devices": n,
    "mesh": "-" if mesh is None else "%dx%d (data x model)" % (
        n // model_par, model_par),
    "gen_tok_s": round(gen, 1), "decode_tok_s": round(dec, 1),
    "ttft_ms": round(ttft * 1e3, 1),
    "decode_collectives": counts[0],
    "collectives_flat": all(c == counts[0] for c in counts)}))
"""


def serve_device_scaling(smoke: bool = False):
    """Decode throughput of the mesh-sharded engine per device count.

    Each cell runs in a subprocess so XLA_FLAGS can force that cell's host
    device count before jax initializes; the 1-device cell is the mesh-free
    engine (the baseline the speedup column normalizes against).

    Expected regression on this CPU host: the 2-device cell decodes at
    ~0.85x of 1 device. Forced host devices share the same cores, the
    per-device shapes are tiny (d_model <= 128 decode GEMMs), and every
    step pays a fixed collective-dispatch floor — so splitting the model
    axis adds overhead without adding compute. This is the *mechanism*
    sweep, not a speedup claim; the property CI gates on is
    ``collectives_flat`` (textual collective counts identical across the
    n=1 / n=drain_steps decode family, i.e. no collective inside the scan
    body), which is what transfers to a real multi-chip deployment.
    """
    cells = [(1, 1), (2, 2)] if smoke else [(1, 1), (2, 2), (4, 2), (8, 2)]
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH="src" + os.pathsep + ".",
               JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    rows = []
    for n, model_par in cells:
        out = subprocess.run(
            [sys.executable, "-c", _SCALE_SCRIPT, str(n), str(model_par),
             str(int(smoke))],
            capture_output=True, text=True, env=env, cwd=repo)
        if out.returncode != 0:
            raise RuntimeError(
                f"device-scaling cell n={n} failed: {out.stderr[-2000:]}")
        rows.append(json.loads(out.stdout.strip().splitlines()[-1]))
    base = rows[0]["decode_tok_s"] or 1.0
    for r in rows:
        r["decode_speedup_vs_1dev"] = round(r["decode_tok_s"] / base, 2)
    print("note: forced host devices share CPU cores — ~0.85x decode at "
          "2 devices is the expected regression (tiny per-device shapes, "
          "fixed collective-dispatch floor). The gated invariant is "
          "collectives_flat, not speedup.")
    return rows


def serve_throughput(smoke: bool = False):
    """tokens/sec + TTFT across batch sizes, legacy vs fused hot loop."""
    if smoke:
        cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                          d_ff=64, vocab=256, remat="none", dtype="float32")
        batches, max_new, max_len = [1, 8], 8, 64
    else:
        # CPU-reference shape: small enough that the per-token model math
        # does not drown the orchestration costs this benchmark isolates
        # (dispatch count, logits transfer, state copies, host sampling).
        cfg = ModelConfig(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=2048, remat="none", dtype="float32")
        batches, max_new, max_len = [1, 4, 8], 64, 128
    params = init(cfg, jax.random.PRNGKey(0))
    sampler = SamplerConfig(temperature=0.0)
    # Probe length 5 = the first workload length, so its prefill chunk
    # shapes ({4, 1}) are warm at every batch size — TTFT measures the
    # admission path, not a compile.
    ttft_prompt = (np.arange(1, 6, dtype=np.int32) % cfg.vocab).astype(np.int32)

    rows = []
    for b in batches:
        nprng = np.random.default_rng(0)
        make_reqs = partial(_workload, b, cfg.vocab, max_new, nprng)
        legacy = _LegacyEngine(cfg, params, max_batch=b, max_len=max_len,
                               sampler=sampler)
        gen_old, dec_old, ttft_old = _measure(legacy, make_reqs, ttft_prompt)
        fused = ServeEngine(cfg, params, max_batch=b, max_len=max_len,
                            sampler=sampler)
        gen_new, dec_new, ttft_new = _measure(fused, make_reqs, ttft_prompt)
        base = {"batch": b, "prompt_mix": "5..40", "max_new": max_new}
        rows.append(dict(base, path="legacy",
                         gen_tok_s=round(gen_old, 1),
                         decode_tok_s=round(dec_old, 1),
                         ttft_ms=round(ttft_old * 1e3, 1),
                         decode_speedup=1.0))
        rows.append(dict(base, path="fused",
                         gen_tok_s=round(gen_new, 1),
                         decode_tok_s=round(dec_new, 1),
                         ttft_ms=round(ttft_new * 1e3, 1),
                         decode_speedup=round(dec_new / dec_old, 2)))
    return rows


# -- gateway overload benchmark ----------------------------------------------
#
# Poisson-arrival mixed LM + vision load through repro.serving.gateway:
#   capacity  — every request submitted at once into a deep queue; measures
#               the sustainable service rate and the no-overload goodput
#               (and pins the golden token streams for the bit-identity
#               check).
#   unloaded  — Poisson arrivals at ~0.4x the measured capacity; bounded
#               queues stay shallow, TTFT here is the tail-latency baseline.
#   overload  — Poisson arrivals at 2x capacity with bounded per-tenant
#               queues and deadlines: the gateway must shed (with
#               retry-after hints) instead of growing the queue, keep
#               admitted streams bit-identical to the capacity run, and
#               keep goodput at the engine's service rate.


def _gw_cnn():
    """Tiny 2-conv CNN for the vision share of the mixed workload."""
    import types

    from repro.models.cnn import layers as L

    def cnn_init(key, num_classes=10):
        k1, k2, k3 = jax.random.split(key, 3)
        return {"c1": L.init_conv(k1, 3, 3, 8),
                "c2": L.init_conv(k2, 3, 8, 16),
                "head": L.init_fc(k3, 16, num_classes)}

    def cnn_apply(params, x, cfg=None, train=False):
        x = L.conv_block(params["c1"], x, stride=2, padding=1, cfg=cfg,
                         train=train)
        x = L.conv_block(params["c2"], x, stride=2, padding=1, cfg=cfg,
                         train=train)
        x = L.avg_pool_global(x)
        return L.fc_block(params["head"], x, cfg=cfg, relu=False,
                          train=train)

    module = types.SimpleNamespace(init=cnn_init, apply=cnn_apply)
    return module, cnn_init(jax.random.PRNGKey(0))


def _gw_workload(n_req, vocab, max_new, max_len, vision_every=5):
    """Deterministic rid -> request table (same across the three runs, so
    the capacity run's outputs are the golden streams for the others)."""
    rng = np.random.default_rng(7)
    items = []
    for rid in range(n_req):
        if vision_every and rid % vision_every == vision_every - 1:
            img = rng.standard_normal((16, 16, 3)).astype(np.float32)
            items.append(("vision", rid, img))
        else:
            hi = min(25, max_len - max_new - 1)
            L = int(rng.integers(3, hi))
            items.append(("lm", rid, rng.integers(
                0, vocab, size=L).astype(np.int32)))
    return items


async def _gw_run(gw, items, rate_req_s, max_new, deadline_ms, seed,
                  sequential=False):
    """Drive one load-generator run; returns raw outcomes + stats().

    ``rate_req_s`` schedules Poisson arrivals against *absolute* target
    times (sleep only the remaining delta, never re-accumulating sleep
    overshoot): event-loop jitter then produces catch-up bursts instead of
    silently lowering the offered rate, so "2x capacity" stays 2x capacity.
    ``sequential`` is the closed-loop no-queueing baseline: one request in
    flight at a time (arrival rate == completion rate by construction).
    """
    import asyncio

    from repro.serving import DeadlineExceeded, ShedError

    rng = np.random.default_rng(seed)
    tokens, top1 = {}, {}
    sheds, expired = [], []

    async def eat_lm(rid, s):
        try:
            tokens[rid] = await s.result()
        except DeadlineExceeded:
            expired.append(rid)
        except ShedError as e:           # tier-3 shed after queueing
            sheds.append((rid, e.retry_after_s))

    async def eat_vi(rid, t):
        try:
            top1[rid] = int((await t.result()).top1)
        except DeadlineExceeded:
            expired.append(rid)
        except ShedError as e:
            sheds.append((rid, e.retry_after_s))

    tasks = []
    deadlocks = 0
    t0 = time.perf_counter()
    next_arrival = t0
    for kind, rid, payload in items:
        if rate_req_s:
            next_arrival += float(rng.exponential(1.0 / rate_req_s))
            delay = next_arrival - time.perf_counter()
            if delay > 0:
                await asyncio.sleep(delay)
        tenant = "gold" if rid % 2 == 0 else "bronze"
        try:
            if kind == "lm":
                s = await gw.submit_lm(payload, max_new_tokens=max_new,
                                       tenant=tenant, deadline_ms=deadline_ms,
                                       rid=rid)
                coro = eat_lm(rid, s)
            else:
                t = await gw.submit_vision(payload, model="tiny",
                                           precision="<4:4>", tenant=tenant,
                                           deadline_ms=deadline_ms, rid=rid)
                coro = eat_vi(rid, t)
        except ShedError as e:           # shed at admission (the common case)
            sheds.append((rid, e.retry_after_s))
            continue
        if sequential:
            await coro
        else:
            tasks.append(asyncio.ensure_future(coro))
    try:
        await asyncio.wait_for(asyncio.gather(*tasks), timeout=300)
        await gw.drain(timeout=60)
    except (asyncio.TimeoutError, TimeoutError):
        deadlocks = 1                    # a stuck stream IS the failure mode
    wall = time.perf_counter() - t0
    return dict(tokens=tokens, top1=top1, sheds=sheds, expired=expired,
                wall=wall, deadlocks=deadlocks, stats=gw.stats())


def gateway_bench(smoke: bool = False):
    import asyncio

    from repro.serving import (Gateway, GatewayConfig, SamplerConfig,
                               ServeEngine, VisionEngine)

    if smoke:
        cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                          d_ff=64, vocab=256, remat="none", dtype="float32")
        n_req, max_new, max_len, max_batch = 48, 8, 64, 4
    else:
        cfg = ModelConfig(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=2048, remat="none", dtype="float32")
        n_req, max_new, max_len, max_batch = 96, 16, 128, 8
    params = init(cfg, jax.random.PRNGKey(0))
    lm = ServeEngine(cfg, params, max_batch=max_batch, max_len=max_len,
                     sampler=SamplerConfig(temperature=0.0))
    orig_drain = lm.drain_steps
    vision = VisionEngine({"tiny": _gw_cnn()}, backend="int-direct",
                          max_batch=max_batch)
    items = _gw_workload(n_req, cfg.vocab, max_new, max_len)
    weights = {"gold": 2.0, "bronze": 1.0}
    prio = {"gold": 1, "bronze": 0}

    def run_once(rate, queue_depth, deadline_ms, seed, sequential=False):
        gw_cfg = GatewayConfig(queue_depth=queue_depth,
                               tenant_weights=weights, tenant_priority=prio)

        async def main():
            gw = Gateway(lm=lm, vision=vision, cfg=gw_cfg)
            gw.start()
            try:
                return await _gw_run(gw, items, rate, max_new, deadline_ms,
                                     seed, sequential=sequential)
            finally:
                gw.stop()
        out = asyncio.run(main())
        lm.drain_steps = orig_drain      # undo any leftover tier-1 lever
        return out

    # Warm run (populates every prefill-chunk/decode/vision compile) so the
    # timed runs measure serving, not XLA compilation.
    run_once(rate=None, queue_depth=n_req, deadline_ms=None, seed=1)

    # Sustainable rate: everything queued at once into a deep bound — the
    # engine batches maximally, so completed/wall is the service capacity.
    cap = run_once(rate=None, queue_depth=n_req, deadline_ms=None, seed=2)
    n_lm = sum(1 for k, _, _ in items if k == "lm")
    cap_req_s = n_req / cap["wall"]
    deadline = 2_000.0 if smoke else 4_000.0
    # No-overload tail-latency baseline: closed-loop, one request in
    # flight — TTFT here is pure admission + first token, zero queue wait.
    unl = run_once(rate=None, queue_depth=8, deadline_ms=deadline, seed=3,
                   sequential=True)
    # No-overload *goodput* baseline: Poisson at 1x capacity — the same
    # arrival process (and so the same vision micro-batch fragmentation)
    # as the overload run, without sustained excess.
    lod = run_once(rate=1.0 * cap_req_s, queue_depth=2 * max_batch,
                   deadline_ms=deadline, seed=5)
    # 2x sustained overload into tight bounded queues: the gateway must
    # shed (with hints), keep depth bounded, and keep goodput at the
    # no-overload level instead of collapsing under congestion.
    ovl = run_once(rate=2.0 * cap_req_s, queue_depth=2 * max_batch,
                   deadline_ms=deadline, seed=4)

    golden = cap["tokens"], cap["top1"]
    assert len(golden[0]) == n_lm, "capacity run must complete every request"

    def row(name, r, offered_req_s):
        st = r["stats"]
        done_tok = sum(len(t) for t in r["tokens"].values())
        n_done = len(r["tokens"]) + len(r["top1"])
        bit_ok = (all(t == golden[0][rid] for rid, t in r["tokens"].items())
                  and all(v == golden[1][rid] for rid, v in r["top1"].items()))
        return {
            "run": name,
            "offered_req_s": round(offered_req_s, 1),
            "n_req": len(items), "done": n_done,
            "shed": len(r["sheds"]), "expired": len(r["expired"]),
            "shed_rate": round(len(r["sheds"]) / len(items), 3),
            "goodput_tok_s": round(done_tok / r["wall"], 1),
            "ttft_p95_ms": st["ttft_ms"]["p95"] and round(
                st["ttft_ms"]["p95"], 1),
            "ttft_admit_p95_ms": st["ttft_admit_ms"]["p95"] and round(
                st["ttft_admit_ms"]["p95"], 1),
            "max_queue_depth": st["queue"]["max_depth"],
            "queue_bound": st["queue"]["bound"],
            "tier_max": max([e["tier"] for e in st["events"]
                             if "tier" in e], default=0),
            "deadlocks": r["deadlocks"],
            "tokens_bit_identical": bit_ok,
            "retry_after_hints_ok": all(ra > 0 for _, ra in r["sheds"]),
        }

    rows = [row("capacity", cap, cap_req_s),
            row("unloaded-seq", unl, len(items) / unl["wall"]),
            row("loaded-1x", lod, cap_req_s),
            row("overload-2x", ovl, 2.0 * cap_req_s)]
    # Acceptance ratios (PR 7): overload goodput vs the load-matched
    # no-overload (1x) run, and admission-referenced TTFT tail vs the
    # unloaded baseline (submit-referenced TTFT under overload includes
    # the bounded queue wait, which the deadline/shed knobs govern —
    # reported, not ratioed).
    unl_admit = rows[1]["ttft_admit_p95_ms"] or float("nan")
    ovl_admit = rows[3]["ttft_admit_p95_ms"] or float("nan")
    rows[3]["goodput_x_vs_no_overload"] = round(
        rows[3]["goodput_tok_s"] / max(rows[2]["goodput_tok_s"], 1e-9), 3)
    rows[3]["ttft_admit_p95_x_vs_unloaded"] = round(ovl_admit / unl_admit, 2)
    return rows
