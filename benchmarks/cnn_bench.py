"""CNN serving benchmark: batched vision-engine throughput/latency sweep.

The paper's headline metric is CNN inference throughput (Table 3 FPS,
Figs. 14-15 precision sweeps); this module measures the serving-path analog
on the vision engine: images/sec across micro-batch bucket sizes, ⟨W:I⟩
precisions and models, against the unbatched per-image dispatch loop the
pre-engine example used (``VisionEngine(max_batch=1)`` — same prepacked
weights and jitted forward, one image per dispatch). Every cell serves the
same image set, so the sweep isolates exactly what batching buys:
dispatch-count amortization and batched GEMM efficiency.

``cnn_sim_crosscheck`` feeds the measured rows through
``repro.pim.calibrate.crosscheck_measured``: the same (model, image, ⟨W:I⟩)
cells priced on the calibrated NAND-SPIN simulator, with the measured/
simulated fps ratio recorded as a tracked trajectory (the engine measures
the reproduction, the simulator prices the paper's hardware).

``benchmarks.run --only cnn`` renders both tables and writes
``BENCH_cnn.json`` at the repo root; ``--smoke`` shrinks to CI scale with
the same artifact shape.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.serving import VisionEngine, VisionRequest
from repro.serving.vision import MODEL_ZOO as _MODULES

# Throughput rows of the last cnn_throughput() call, reused by the
# simulator cross-check so one benchmark run measures each cell once.
_last_rows: list = []


def _measure(params, model, image, precision, batch, n_images,
             backend="int-direct", repeats=2):
    """Images/sec serving ``n_images`` through max_batch=``batch`` buckets.

    One warm run populates the prepack + compile caches; the timed runs
    then measure the serving path. Returns (img_s, ms_per_image).
    """
    eng = VisionEngine({model: params}, backend=backend, max_batch=batch)
    rng = np.random.default_rng(0)
    imgs = rng.standard_normal((n_images, image, image, 3)).astype(np.float32)

    def serve():
        for rid in range(n_images):
            eng.submit(VisionRequest(rid=rid, image=imgs[rid], model=model,
                                     precision=precision))
        return eng.run()

    serve()                                   # warm: prepack + compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        done = serve()
    dt = (time.perf_counter() - t0) / repeats
    assert len(done) == n_images
    return n_images / dt, dt / n_images * 1e3


def cnn_throughput(smoke: bool = False):
    """img/s + per-image latency across (model, precision, bucket size)."""
    if smoke:
        cells = [("alexnet", 64)]
        precisions = ["<8:8>"]
        batches = [1, 8]
        n_images = 8
    else:
        cells = [("alexnet", 64), ("resnet50", 32)]
        precisions = ["<4:4>", "<8:8>"]
        batches = [1, 2, 4, 8]
        n_images = 16
    rows = []
    for model, image in cells:
        params = _MODULES[model].init(jax.random.PRNGKey(0), image=image,
                                      num_classes=16)
        for precision in precisions:
            base = None
            for b in batches:
                img_s, ms = _measure(params, model, image, precision, b,
                                     n_images)
                if base is None:
                    base = img_s
                rows.append({
                    "model": model, "image": image, "precision": precision,
                    "backend": "int-direct", "batch": b,
                    "n_images": n_images,
                    "img_s": round(img_s, 2), "ms_per_image": round(ms, 2),
                    "speedup_vs_unbatched": round(img_s / base, 2),
                })
    _last_rows[:] = rows
    return rows


def cnn_sim_crosscheck(smoke: bool = False):
    """Measured engine fps vs calibrated NAND-SPIN simulator fps."""
    from repro.pim.calibrate import crosscheck_measured

    rows = _last_rows
    if not rows:                    # --only filtered out the throughput run
        params = _MODULES["alexnet"].init(jax.random.PRNGKey(0), image=64,
                                          num_classes=16)
        n = 8 if smoke else 16
        img_s, _ = _measure(params, "alexnet", 64, "<8:8>", 8, n)
        rows = [{"model": "alexnet", "image": 64, "precision": "<8:8>",
                 "backend": "int-direct", "batch": 8,
                 "img_s": round(img_s, 2)}]
    # One cross-check row per (model, precision): the largest bucket is the
    # serving configuration; smaller buckets only quantify batching.
    best = {}
    for r in rows:
        key = (r["model"], r["precision"])
        if key not in best or r["batch"] > best[key]["batch"]:
            best[key] = r
    return crosscheck_measured(list(best.values()))
