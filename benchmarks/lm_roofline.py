"""Roofline table over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/*.json (written by repro.launch.dryrun) and renders
per (arch x shape x mesh): the three roofline terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs usefulness ratio, and the roofline fraction.
"""
from __future__ import annotations

import json
import os

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(_ROOT, "results", "dryrun")
BASELINE = os.path.join(_ROOT, "results", "dryrun_baseline")


def load_cells(mesh_filter: str | None = None, directory: str | None = None,
               variants: bool = False):
    cells = []
    d = directory or RESULTS
    if not os.path.isdir(d):
        return cells
    for f in sorted(os.listdir(d)):
        if not f.endswith(".json"):
            continue
        if not variants and f.count("__") > 2:   # variant-tagged cells
            continue
        with open(os.path.join(d, f)) as fh:
            r = json.load(fh)
        if mesh_filter and mesh_filter not in r.get("mesh", ""):
            continue
        cells.append(r)
    return cells


def roofline_table(mesh_filter: str = "16x16:data", directory: str | None = None):
    """Single-pod roofline rows (the §Roofline deliverable)."""
    rows = []
    for r in load_cells(directory=directory):
        mesh = r.get("mesh", "")
        if not mesh.startswith("16x16"):
            continue
        if "skipped" in r:
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "bottleneck": "SKIP", "t_compute_ms": "-",
                         "t_memory_ms": "-", "t_collective_ms": "-",
                         "useful_flops": "-", "roofline_frac": "-"})
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "t_compute_ms": round(rf["t_compute_s"] * 1e3, 2),
            "t_memory_ms": round(rf["t_memory_s"] * 1e3, 2),
            "t_collective_ms": round(rf["t_collective_s"] * 1e3, 2),
            "bottleneck": rf["bottleneck"],
            "useful_flops": round(rf["useful_flops_fraction"], 3),
            "roofline_frac": round(rf["roofline_fraction"], 3),
        })
    return rows


def multipod_check():
    """Multi-pod (2x16x16) compile status per cell (§Dry-run)."""
    rows = []
    for r in load_cells():
        if not r.get("mesh", "").startswith("2x16x16"):
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "status": "SKIP" if "skipped" in r else "compiled",
            "compile_s": r.get("compile_s", "-"),
            "collective_wire_GB_per_chip": (
                "-" if "skipped" in r else
                round(r["collectives"]["wire_bytes_per_chip"] / 1e9, 3)),
        })
    return rows


def baseline_vs_optimized():
    """Per-cell roofline-fraction delta: pre-optimization framework
    (results/dryrun_baseline) vs final (results/dryrun), single-pod."""
    base = {(r["arch"], r["shape"]): r for r in load_cells(directory=BASELINE)
            if r.get("mesh", "").startswith("16x16")}
    rows = []
    for r in load_cells():
        if not r.get("mesh", "").startswith("16x16") or "skipped" in r:
            continue
        b = base.get((r["arch"], r["shape"]))
        if b is None or "skipped" in b:
            continue
        bf = b["roofline"]
        of = r["roofline"]
        bound_b = max(bf["t_compute_s"], bf["t_memory_s"], bf["t_collective_s"])
        bound_o = max(of["t_compute_s"], of["t_memory_s"], of["t_collective_s"])
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "bound_before_s": round(bound_b, 3),
            "bound_after_s": round(bound_o, 3),
            "speedup": round(bound_b / bound_o, 2) if bound_o else "-",
            "frac_before": round(bf["roofline_fraction"], 4),
            "frac_after": round(of["roofline_fraction"], 4),
        })
    return rows
