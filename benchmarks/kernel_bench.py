"""Pallas bit-serial kernel benchmark: tile-plan sweep + backend comparison.

On this CPU container the Pallas kernel runs in interpret mode (semantics,
not speed), so the *wall-clock* comparison across backends uses the XLA
expressions of the same algorithm (popcount / mxu-plane / int-direct) and
the tile sweep reports the planner's VMEM working sets for the TPU target —
the quantity BlockSpec tiling actually optimizes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.bitserial import int_matmul
from repro.core.mapping import plan_matmul


def _bench(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def backend_comparison():
    """Wall-clock of the three Eq.-1 execution strategies across <W:I>.

    The DESIGN §2 trade-off experiment: the paper-faithful popcount
    dataflow scales with W*I plane pairs, the MXU bit-plane path pays one
    {0,1} contraction per pair, the direct integer matmul is constant in
    precision — 'which wins at which precision' quantified (CPU reference
    numbers; the structural trend carries to TPU where the MXU advantage
    grows)."""
    rows = []
    m, k, n = 256, 2048, 256
    key = jax.random.PRNGKey(0)
    for bits in (2, 4, 8):
        qa = jax.random.randint(key, (m, k), 0, 2**bits)
        qw = jax.random.randint(jax.random.fold_in(key, 1), (k, n), 0, 2**bits)
        for backend in ("popcount", "mxu-plane", "int-direct"):
            f = jax.jit(lambda a, w, b=backend, bb=bits: int_matmul(a, w, bb, bb, b))
            dt = _bench(f, qa, qw)
            rows.append({"W:I": f"<{bits}:{bits}>", "backend": backend,
                         "m_k_n": f"{m}x{k}x{n}", "ms": round(dt * 1e3, 2),
                         "GOPS_int": round(2 * m * k * n / dt / 1e9, 1)})
    return rows


def tile_plan_sweep():
    """BlockSpec tile plans across GEMM shapes: VMEM working set vs grid."""
    rows = []
    for (m, k, n) in [(128, 1024, 128), (1024, 4096, 1024),
                      (4096, 4096, 4096), (256, 32768, 256),
                      (8192, 1024, 8192)]:
        for (ab, wb) in [(4, 4), (8, 8)]:
            p = plan_matmul(m, k, n, ab, wb)
            rows.append({
                "MxKxN": f"{m}x{k}x{n}", "W:I": f"<{wb}:{ab}>",
                "bm": p.bm, "bn": p.bn, "bk_bits": p.bk_bits,
                "grid": "x".join(map(str, p.grid)),
                "vmem_KB": round(p.vmem_bytes / 1024, 1),
            })
    return rows
