"""Pallas bit-serial kernel benchmark: tile-plan sweep + backend comparison.

On this CPU container the Pallas kernel runs in interpret mode (semantics,
not speed), so the *wall-clock* comparison across backends uses the XLA
expressions of the same algorithm (popcount / mxu-plane / int-direct) and
the tile sweep reports the planner's VMEM working sets for the TPU target —
the quantity BlockSpec tiling actually optimizes.

``serving_path_comparison`` is the perf-trajectory anchor for the prepack
fast path: a decode-shaped GEMM where the weight-side calibrate->quantize->
pack either re-runs every call (seed behaviour) or ran once at deployment
(``PackedWeight``, the paper's program-subarrays-once step).

``benchmarks.run`` reuses each section's rows for the ``BENCH_kernels.json``
artifact it writes to the repo root.
"""
from __future__ import annotations

import time

import jax

from repro.core import PIMQuantConfig, fuse_conv_heuristic, pim_conv2d, prepack_conv2d
from repro.core.bitserial import int_matmul, quantized_matmul
from repro.core.mapping import plan_matmul
from repro.core.packed import prepack
from repro.kernels.ops import matmul_tiles


def _bench(fn, *args, iters=3):
    out = fn(*args)              # warm-up / compile, evaluated exactly once
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def _tiles_used(backend, m, k, n, a_bits, w_bits):
    """The blocking a backend actually ran with, recorded per row so a
    perf delta between artifacts is attributable to a tiling change.
    popcount chunks output columns (core.bitserial._N_CHUNK); pallas runs
    the legalized BlockSpec tiles; the rest are single XLA fusions."""
    if backend == "popcount":
        return "n_chunk=128"
    if backend == "pallas":
        bm, bn, bkw = matmul_tiles(m, n, -(-k // 32), a_bits, w_bits)
        return f"bm{bm}xbn{bn}xbkw{bkw}"
    return "xla-fused"


def backend_comparison():
    """Wall-clock of the three Eq.-1 execution strategies across <W:I>.

    The DESIGN §2 trade-off experiment: the paper-faithful popcount
    dataflow scales with W*I plane pairs, the MXU bit-plane path pays one
    {0,1} contraction per pair, the direct integer matmul is constant in
    precision — 'which wins at which precision' quantified (CPU reference
    numbers; the structural trend carries to TPU where the MXU advantage
    grows)."""
    rows = []
    m, k, n = 256, 2048, 256
    key = jax.random.PRNGKey(0)
    for bits in (2, 4, 8):
        qa = jax.random.randint(key, (m, k), 0, 2**bits)
        qw = jax.random.randint(jax.random.fold_in(key, 1), (k, n), 0, 2**bits)
        for backend in ("popcount", "mxu-plane", "int-direct"):
            f = jax.jit(lambda a, w, b=backend, bb=bits: int_matmul(a, w, bb, bb, b))
            dt = _bench(f, qa, qw)
            rows.append({"W:I": f"<{bits}:{bits}>", "backend": backend,
                         "tiles": _tiles_used(backend, m, k, n, bits, bits),
                         "m_k_n": f"{m}x{k}x{n}", "ms": round(dt * 1e3, 2),
                         "GOPS_int": round(2 * m * k * n / dt / 1e9, 1)})
    return rows


def serving_path_comparison():
    """Cached ``PackedWeight`` vs per-call quantize+pack at <8:8>.

    Decode-shaped GEMM (small M, big weight): exactly the regime where the
    paper's one-time subarray programming pays, because the per-call path's
    weight-side work is O(K*N) regardless of batch. CPU reference numbers."""
    rows = []
    m, k, n = 4, 2048, 2048
    key = jax.random.PRNGKey(7)
    a = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    for backend in ("popcount", "int-direct"):
        percall = jax.jit(lambda a, w, b=backend: quantized_matmul(
            a, w, 8, 8, backend=b))
        pk = prepack(w, 8)
        cached = jax.jit(lambda a, pk, b=backend: quantized_matmul(
            a, pk, a_bits=8, backend=b))
        t_per = _bench(percall, a, w)
        t_cached = _bench(cached, a, pk)
        rows.append({
            "W:I": "<8:8>", "backend": backend,
            "tiles": _tiles_used(backend, m, k, n, 8, 8),
            "m_k_n": f"{m}x{k}x{n}",
            "per_call_ms": round(t_per * 1e3, 3),
            "cached_ms": round(t_cached * 1e3, 3),
            "speedup": round(t_per / t_cached, 2),
        })
    return rows


def fused_conv_comparison():
    """Fused implicit-im2col conv vs materialized patch matrix.

    Reports wall-clock for the XLA-backed materialized path and the Pallas
    fused path (interpret mode on CPU — semantics, not speed), plus the HBM
    bytes the fused path never allocates. The structural claim (no
    (N*OH*OW, KH*KW*C) intermediate) is asserted by jaxpr inspection in
    tests/test_fastpath.py."""
    rows = []
    n, h, c, o, kk = 2, 16, 32, 32, 3
    x = jax.random.normal(jax.random.PRNGKey(3), (n, h, h, c))
    w = jax.random.normal(jax.random.PRNGKey(4), (kk, kk, c, o)) * 0.1
    for stride, pad in [(1, 1), (2, 1)]:
        cfg = PIMQuantConfig(8, 8, backend="pallas")
        pk = prepack_conv2d(w, cfg)
        oh = (h + 2 * pad - kk) // stride + 1
        im2col_kb = 4 * n * oh * oh * kk * kk * c / 1024
        f_fused = jax.jit(lambda x, pk, s=stride, p=pad: pim_conv2d(
            x, pk, stride=s, padding=p, cfg=cfg, conv_mode="fused"))
        cfg_mat = PIMQuantConfig(8, 8, backend="int-direct")
        f_mat = jax.jit(lambda x, pk, s=stride, p=pad: pim_conv2d(
            x, pk, stride=s, padding=p, cfg=cfg_mat, conv_mode="im2col"))
        rows.append({
            "NHWC/O/k": f"{n}x{h}x{h}x{c}/{o}/{kk}", "stride": stride,
            "pad": pad,
            "im2col_backend": cfg_mat.backend,
            "fused_bo": min(128, o),   # kernel's O block after legalization
            "im2col_ms": round(_bench(f_mat, x, pk) * 1e3, 2),
            "fused_ms_interp": round(_bench(f_fused, x, pk) * 1e3, 2),
            "im2col_HBM_KB_avoided": round(im2col_kb, 1),
            "auto_would_fuse": fuse_conv_heuristic(
                n, oh, oh, kk, kk, c, "pallas"),
        })
    return rows


def tile_plan_sweep():
    """BlockSpec tile plans across GEMM shapes: VMEM working set vs grid."""
    rows = []
    for (m, k, n) in [(128, 1024, 128), (1024, 4096, 1024),
                      (4096, 4096, 4096), (256, 32768, 256),
                      (8192, 1024, 8192)]:
        for (ab, wb) in [(4, 4), (8, 8)]:
            p = plan_matmul(m, k, n, ab, wb)
            rows.append({
                "MxKxN": f"{m}x{k}x{n}", "W:I": f"<{wb}:{ab}>",
                "bm": p.bm, "bn": p.bn, "bk_bits": p.bk_bits,
                "grid": "x".join(map(str, p.grid)),
                "vmem_KB": round(p.vmem_bytes / 1024, 1),
            })
    return rows

