"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run [--only X]``.

Sections map 1:1 onto the paper's tables/figures (+ the TPU-side roofline
artifacts). Each renders as an aligned text table. Kernel sections are
additionally written to ``BENCH_kernels.json``, the serving section to
``BENCH_serving.json``, the vision section to ``BENCH_cnn.json`` and the
fault sections to ``BENCH_faults.json`` at the repo root so future PRs can
track the perf trajectory (cached-weight vs per-call serving, fused-conv
vs im2col, backend sweep, engine hot-loop tokens/sec + TTFT,
accuracy-vs-BER mitigation frontier). The MoE sections (packed expert
banks vs float einsum, expert-parallel/pipelined engine scaling) also land
in ``BENCH_serving.json`` under ``moe_layer``/``moe_device_scaling``.
``--smoke`` shrinks the serving and fault benchmarks to CI scale without
changing the artifact shape.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time


def render(title: str, rows: list) -> None:
    print(f"\n== {title} " + "=" * max(1, 70 - len(title)))
    if not rows:
        print("  (no rows — run the producing step first)")
        return
    cols = list(rows[0].keys())
    widths = {c: max(len(str(c)), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    print("  " + "  ".join(str(c).ljust(widths[c]) for c in cols))
    for r in rows:
        print("  " + "  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on section names")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale serving benchmark (same artifact shape)")
    args = ap.parse_args(argv)

    from . import (autotune_bench, cnn_bench, fault_bench, kernel_bench,
                   lm_roofline, moe_bench, paper_figures, serve_bench)

    serve_throughput = functools.partial(serve_bench.serve_throughput,
                                         smoke=args.smoke)
    serve_scaling = functools.partial(serve_bench.serve_device_scaling,
                                      smoke=args.smoke)
    moe_layer = functools.partial(moe_bench.moe_layer_comparison,
                                  smoke=args.smoke)
    moe_scaling = functools.partial(moe_bench.moe_device_scaling,
                                    smoke=args.smoke)
    serve_gateway = functools.partial(serve_bench.gateway_bench,
                                      smoke=args.smoke)
    cnn_throughput = functools.partial(cnn_bench.cnn_throughput,
                                       smoke=args.smoke)
    cnn_crosscheck = functools.partial(cnn_bench.cnn_sim_crosscheck,
                                       smoke=args.smoke)
    fault_frontier = functools.partial(fault_bench.fault_frontier,
                                       smoke=args.smoke)
    autotune_regret = functools.partial(autotune_bench.autotune_regret,
                                        smoke=args.smoke)
    sections = [
        ("fig13a: capacity sweep", paper_figures.fig13a_capacity_sweep),
        ("fig13b: bandwidth sweep", paper_figures.fig13b_bandwidth_sweep),
        ("fig14: energy efficiency vs counterparts", paper_figures.fig14_energy_efficiency),
        ("fig15: per-area speedup vs counterparts", paper_figures.fig15_speedup),
        ("table3: accelerator comparison", paper_figures.table3_comparison),
        ("fig16: latency/energy breakdown (resnet50)", paper_figures.fig16_breakdown),
        ("fig17: add-on area breakdown", paper_figures.fig17_area_overhead),
        ("paper-claims check (§5.3)", paper_figures.paper_claims_check),
        ("kernel: Eq.1 backend comparison (CPU)", kernel_bench.backend_comparison),
        ("kernel: cached PackedWeight vs per-call quantize+pack",
         kernel_bench.serving_path_comparison),
        ("kernel: fused implicit-im2col conv vs materialized",
         kernel_bench.fused_conv_comparison),
        ("kernel: BlockSpec tile plans (TPU target)", kernel_bench.tile_plan_sweep),
        # "autotune:" (not "kernel:") so `--only kernel` stays the quick
        # kernel sweep and `--only autotune` selects the regret bench.
        ("autotune: picked-vs-best regret (cost model vs exhaustive)",
         autotune_regret),
        ("roofline: single-pod 16x16 (from dry-run)", lm_roofline.roofline_table),
        ("dry-run: multi-pod 2x16x16 compile status", lm_roofline.multipod_check),
        ("perf: baseline vs optimized step-time bound", lm_roofline.baseline_vs_optimized),
        ("serve: engine throughput (legacy vs fused hot loop)", serve_throughput),
        ("serve: device-count scaling (chips=data x banks=model mesh)",
         serve_scaling),
        ("serve: MoE expert FFN packed vs float einsum (per-layer)",
         moe_layer),
        ("serve: MoE engine scaling (experts=chips / pipeline stages)",
         moe_scaling),
        ("serve: overload gateway (Poisson mixed LM+vision load-gen)",
         serve_gateway),
        ("cnn: vision engine throughput (batch x precision x model)",
         cnn_throughput),
        ("cnn: measured vs simulated fps (pim.calibrate cross-check)",
         cnn_crosscheck),
        ("faults: accuracy-vs-BER frontier (ECC on/off)", fault_frontier),
        ("faults: mitigation overhead (redundancy x, die area)",
         fault_bench.fault_overhead),
    ]
    # Kernel sections feeding BENCH_kernels.json (rows reused, not re-run).
    json_keys = {
        kernel_bench.serving_path_comparison: "serving_cached_vs_percall",
        kernel_bench.fused_conv_comparison: "fused_conv_vs_im2col",
        kernel_bench.backend_comparison: "backend_comparison",
        kernel_bench.tile_plan_sweep: "tile_plans",
        autotune_regret: "autotune_regret",
    }
    payload = {}
    serve_payload = {}
    cnn_payload = {}
    fault_payload = {}
    t0 = time.time()
    failures = []
    for title, fn in sections:
        if args.only and args.only not in title:
            continue
        try:
            rows = fn()
            render(title, rows)
            if fn in json_keys:
                payload[json_keys[fn]] = rows
            elif fn is serve_throughput:
                serve_payload["serve_throughput"] = rows
            elif fn is serve_scaling:
                serve_payload["device_scaling"] = rows
            elif fn is moe_layer:
                serve_payload["moe_layer"] = rows
            elif fn is moe_scaling:
                serve_payload["moe_device_scaling"] = rows
            elif fn is serve_gateway:
                serve_payload["gateway"] = rows
            elif fn is cnn_throughput:
                cnn_payload["throughput"] = rows
            elif fn is cnn_crosscheck:
                cnn_payload["sim_crosscheck"] = rows
            elif fn is fault_frontier:
                fault_payload["frontier"] = rows
            elif fn is fault_bench.fault_overhead:
                fault_payload["overhead"] = rows
            if serve_payload:
                serve_payload["smoke"] = args.smoke
            if cnn_payload:
                cnn_payload["smoke"] = args.smoke
            if fault_payload:
                fault_payload["smoke"] = args.smoke
        except Exception as e:  # keep the suite running; report at the end
            failures.append((title, repr(e)))
            print(f"\n== {title} FAILED: {e!r}")
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for data, name in ((payload, "BENCH_kernels.json"),
                       (serve_payload, "BENCH_serving.json"),
                       (cnn_payload, "BENCH_cnn.json"),
                       (fault_payload, "BENCH_faults.json")):
        if not data:
            continue
        path = os.path.join(repo_root, name)
        try:
            # Merge over the committed artifact so a filtered run (--only
            # matching one section) or a section failure updates its own
            # keys without destroying the rows other sections produced.
            old = {}
            if os.path.exists(path):
                with open(path) as fh:
                    old = json.load(fh)
                data = {**old, **data}
            if name == "BENCH_serving.json" and old.get("device_scaling") \
                    and not data.get("device_scaling"):
                # Loud failure, never a silent skip: losing the committed
                # device-scaling rows means a section-wiring bug upstream
                # (the merge above is what preserves them on filtered runs).
                raise RuntimeError(
                    "refusing to rewrite BENCH_serving.json: it would drop "
                    "the committed device_scaling rows (section produced "
                    f"{data.get('device_scaling')!r})")
            with open(path, "w") as fh:
                json.dump(data, fh, indent=1)
            print(f"\nwrote {path}")
        except Exception as e:
            failures.append((name, repr(e)))

    print(f"\nbenchmarks done in {time.time() - t0:.1f}s")
    if failures:
        for t, e in failures:
            print("FAILED:", t, e)
        sys.exit(1)


if __name__ == "__main__":
    main()
