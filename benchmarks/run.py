"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run [--only X]``.

Sections map 1:1 onto the paper's tables/figures (+ the TPU-side roofline
artifacts). Each renders as an aligned text table.
"""
from __future__ import annotations

import argparse
import sys
import time


def render(title: str, rows: list) -> None:
    print(f"\n== {title} " + "=" * max(1, 70 - len(title)))
    if not rows:
        print("  (no rows — run the producing step first)")
        return
    cols = list(rows[0].keys())
    widths = {c: max(len(str(c)), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    print("  " + "  ".join(str(c).ljust(widths[c]) for c in cols))
    for r in rows:
        print("  " + "  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on section names")
    args = ap.parse_args(argv)

    from . import kernel_bench, lm_roofline, paper_figures

    sections = [
        ("fig13a: capacity sweep", paper_figures.fig13a_capacity_sweep),
        ("fig13b: bandwidth sweep", paper_figures.fig13b_bandwidth_sweep),
        ("fig14: energy efficiency vs counterparts", paper_figures.fig14_energy_efficiency),
        ("fig15: per-area speedup vs counterparts", paper_figures.fig15_speedup),
        ("table3: accelerator comparison", paper_figures.table3_comparison),
        ("fig16: latency/energy breakdown (resnet50)", paper_figures.fig16_breakdown),
        ("fig17: add-on area breakdown", paper_figures.fig17_area_overhead),
        ("paper-claims check (§5.3)", paper_figures.paper_claims_check),
        ("kernel: Eq.1 backend comparison (CPU)", kernel_bench.backend_comparison),
        ("kernel: BlockSpec tile plans (TPU target)", kernel_bench.tile_plan_sweep),
        ("roofline: single-pod 16x16 (from dry-run)", lm_roofline.roofline_table),
        ("dry-run: multi-pod 2x16x16 compile status", lm_roofline.multipod_check),
        ("perf: baseline vs optimized step-time bound", lm_roofline.baseline_vs_optimized),
    ]
    t0 = time.time()
    failures = []
    for title, fn in sections:
        if args.only and args.only not in title:
            continue
        try:
            render(title, fn())
        except Exception as e:  # keep the suite running; report at the end
            failures.append((title, repr(e)))
            print(f"\n== {title} FAILED: {e!r}")
    print(f"\nbenchmarks done in {time.time() - t0:.1f}s")
    if failures:
        for t, e in failures:
            print("FAILED:", t, e)
        sys.exit(1)


if __name__ == "__main__":
    main()
