"""MoE fast-path benchmark: packed expert banks vs the float-einsum path.

Two sweeps over the phi3.5-MoE family (reduced to CPU scale), both landing
in ``BENCH_serving.json``:

``moe_layer_comparison`` — per-layer decode-shape latency of ``moe_ffn``
with prepacked expert banks (``prepack_params``: expert-stacked (E, K, N)
bit-plane layout, fused quantize->pack dispatch) against the same routing
over the float einsum path (the pre-packing behavior: router-bearing dicts
served as f32), across <2:2>/<4:4>/<8:8> and two expert widths. At the
reduced width the call is dispatch-bound on CPU; at the wide shape the
bit-serial GEMMs dominate and the packed path's advantage is the paper's
many-planes-in-parallel story (packed >= 1.5x float at <4:4>, asserted by
``--smoke``). Long-context prefill shapes favor float on CPU — the packed
win is a *decode* (tokens-per-step ~ batch) property, which is exactly the
serving hot loop.

``moe_device_scaling`` — engine decode tokens/sec per device count
(1/2/4/8, each cell a subprocess so XLA_FLAGS can force the host device
count) on the expert-parallel mesh ("model" axis divides E: experts =
chips, DESIGN.md §11), plus a pipeline-composed cell (``pipeline_stages``)
where depth factors. Rows carry the routing-overflow telemetry
(``stats()["moe_drop_frac"]``) so the sweep also exercises the drop ring
end to end. As with ``serve_device_scaling``, CPU cells share cores — the
gate is mechanism (flat collective counts, EP layout), not speedup.

Run standalone (merges its keys into BENCH_serving.json):

  PYTHONPATH=src python -m benchmarks.moe_bench --smoke

or through ``benchmarks.run --only serve``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp


def _moe_cfg(w_bits: int = 4, a_bits: int = 4, wide: bool = False,
             backend: str = "popcount", **overrides):
    """phi3.5-MoE reduced to CPU scale (4 experts, top-2), float32 masters,
    bit-serial expert banks at the given precision. ``wide=True`` doubles
    the expert GEMMs to the regime where the bit-plane kernels dominate
    the dispatch overhead."""
    from repro.configs import get_config
    from repro.core.pim_layers import PIMQuantConfig

    arch = get_config("phi3.5-moe-42b-a6.6b")
    if wide:
        overrides = dict(d_model=256, d_ff=512, **overrides)
    return arch.model.reduced(
        dtype="float32",
        pim=PIMQuantConfig(w_bits=w_bits, a_bits=a_bits, backend=backend),
        **overrides)


def _time_layer(cfg, params, x, reps: int) -> float:
    """Best-of-3 mean latency (ms) of one jitted ``moe_ffn`` call."""
    from repro.models.lm.moe import moe_ffn

    f = jax.jit(lambda p, xr: moe_ffn(p, cfg, xr)[0])
    f(params, x).block_until_ready()
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(reps):
            f(params, x).block_until_ready()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best * 1e3


def moe_layer_comparison(smoke: bool = False):
    """Per-layer packed-vs-float latency rows (decode shape, batch 8)."""
    from repro.models.lm.model import prepack_params
    from repro.models.lm.moe import init_moe

    reps = 20 if smoke else 60
    rows = []
    for wide in (False, True):
        for bits in (2, 4, 8):
            cfg = _moe_cfg(w_bits=bits, a_bits=bits, wide=wide)
            params = init_moe(cfg, jax.random.PRNGKey(0))
            packed = prepack_params(params, cfg.pim)
            x = jax.random.normal(jax.random.PRNGKey(1),
                                  (8, 1, cfg.d_model), jnp.float32) * 0.3
            t_float = _time_layer(cfg, params, x, reps)
            t_packed = _time_layer(cfg, packed, x, reps)
            rows.append({
                "precision": cfg.pim.tag,
                "experts": f"{cfg.moe.n_experts}top{cfg.moe.top_k}",
                "d_model": cfg.d_model, "d_ff": cfg.d_ff,
                "tokens": 8, "backend": cfg.pim.backend,
                "float_ms": round(t_float, 3),
                "packed_ms": round(t_packed, 3),
                "packed_speedup": round(t_float / t_packed, 2),
            })
    return rows


_MOE_SCALE_SCRIPT = r"""
import sys
n, model_par, stages, smoke = (int(v) for v in sys.argv[1:5])
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d" % n
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
from functools import partial
import jax
import numpy as np
from benchmarks.moe_bench import _moe_cfg
from benchmarks.serve_bench import _measure, _workload
from repro.launch.mesh import make_serve_mesh
from repro.models.lm import init
from repro.serving import SamplerConfig, ServeEngine

cfg = _moe_cfg(w_bits=4, a_bits=4, wide=not smoke, n_layers=4)
params = init(cfg, jax.random.PRNGKey(0))
mesh = make_serve_mesh(model_par) if model_par > 1 else None
eng = ServeEngine(cfg, params, max_batch=8, max_len=64,
                  sampler=SamplerConfig(temperature=0.0), mesh=mesh,
                  pipeline_stages=stages)
rng = np.random.default_rng(0)
max_new = 8 if smoke else 24
make_reqs = partial(_workload, 8, cfg.vocab, max_new, rng)
ttft_prompt = (np.arange(1, 6, dtype=np.int32) % cfg.vocab).astype(np.int32)
gen, dec, ttft = _measure(eng, make_reqs, ttft_prompt)
drop = eng.stats()["moe_drop_frac"]
if stages > 1:
    mode, mesh_s = "pipeline", "%d stages" % stages
elif mesh is not None:
    mode = "expert-parallel" if cfg.moe.n_experts % model_par == 0 else "tp"
    mesh_s = "%dx%d (data x model)" % (n // model_par, model_par)
else:
    mode, mesh_s = "single", "-"
print(json.dumps({
    "devices": n, "mode": mode, "mesh": mesh_s,
    "gen_tok_s": round(gen, 1), "decode_tok_s": round(dec, 1),
    "ttft_ms": round(ttft * 1e3, 1),
    "moe_drop_frac_mean": drop["mean"] and round(drop["mean"], 4)}))
"""


def moe_device_scaling(smoke: bool = False):
    """MoE engine decode throughput per device count on the EP mesh.

    Cells: 1 device (mesh-free baseline), 2/4/8 devices with 2-way "model"
    parallelism (E=4 experts split 2-way: the experts=chips mapping), and
    a 2-stage pipelined cell (depth 4 factors into 2 stages)."""
    cells = [(1, 1, 1), (2, 2, 1), (2, 1, 2)] if smoke else \
        [(1, 1, 1), (2, 2, 1), (4, 2, 1), (8, 2, 1), (2, 1, 2)]
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH="src" + os.pathsep + ".",
               JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    rows = []
    for n, model_par, stages in cells:
        out = subprocess.run(
            [sys.executable, "-c", _MOE_SCALE_SCRIPT, str(n),
             str(model_par), str(stages), str(int(smoke))],
            capture_output=True, text=True, env=env, cwd=repo)
        if out.returncode != 0:
            raise RuntimeError(
                f"moe-scaling cell n={n} mp={model_par} s={stages} "
                f"failed: {out.stderr[-2000:]}")
        rows.append(json.loads(out.stdout.strip().splitlines()[-1]))
    base = rows[0]["decode_tok_s"] or 1.0
    for r in rows:
        r["decode_speedup_vs_1dev"] = round(r["decode_tok_s"] / base, 2)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m benchmarks.moe_bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale + assert packed beats float at <4:4> "
                    "(>= 1.5x at the wide expert shape)")
    args = ap.parse_args(argv)

    from .run import render

    layer = moe_layer_comparison(smoke=args.smoke)
    render("serve: MoE expert FFN packed vs float einsum (per-layer)", layer)
    scale = moe_device_scaling(smoke=args.smoke)
    render("serve: MoE engine scaling (experts=chips / pipeline)", scale)

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(repo, "BENCH_serving.json")
    data = {}
    if os.path.exists(path):
        with open(path) as fh:
            data = json.load(fh)
    data["moe_layer"] = layer
    data["moe_device_scaling"] = scale
    with open(path, "w") as fh:
        json.dump(data, fh, indent=1)
    print(f"\nwrote {path}")

    if args.smoke:
        at44 = [r for r in layer if r["precision"] == "<4:4>"]
        assert at44, layer
        worst = min(r["packed_speedup"] for r in at44)
        best = max(r["packed_speedup"] for r in at44)
        assert worst > 1.0, ("packed expert FFN must beat the float "
                            "einsum at <4:4>", at44)
        assert best >= 1.5, ("packed expert FFN must reach 1.5x float "
                             "at the wide <4:4> shape", at44)
        print(f"moe smoke OK: packed {worst:.2f}x..{best:.2f}x "
              f"float at <4:4>")
    return 0


if __name__ == "__main__":
    sys.exit(main())
