"""Benchmarks reproducing each table/figure of the paper (§5).

Each ``fig*``/``table*`` function computes one artifact and returns rows;
``benchmarks.run`` drives them all and renders aligned text tables.
"""
from __future__ import annotations

from repro.pim.area import add_on_area_mm2, chip_area_mm2
from repro.pim.baselines import (
    COUNTERPARTS, MODELS, WI_CONFIGS, energy_table, speedup_table,
)
from repro.pim.calibrate import PAPER_CLAIMS
from repro.pim.hierarchy import Geometry
from repro.pim.simulator import peak_gops, simulate_model


def fig13a_capacity_sweep():
    """Peak performance & energy efficiency vs memory capacity (Fig. 13a)."""
    rows = []
    for cap in (8, 16, 32, 64, 128, 256):
        g = Geometry().with_capacity(cap)
        area = chip_area_mm2(g)
        perf = peak_gops(g)
        r = simulate_model("resnet50", geometry=g)
        rows.append({
            "capacity_MB": cap,
            "peak_GOPS": round(perf, 1),
            "perf_per_area": round(perf / area, 2),
            "fps": round(r.fps, 1),
            "fps_per_W": round(r.fps / (r.energy * r.fps), 2),
        })
    return rows


def fig13b_bandwidth_sweep():
    """Peak performance & utilization vs bus width (Fig. 13b)."""
    rows = []
    for bus in (32, 64, 128, 256, 512):
        g = Geometry().with_bus(bus)
        r = simulate_model("resnet50", geometry=g)
        load = r.phases["load"].latency
        busy = r.latency - load
        rows.append({
            "bus_bits": bus,
            "fps": round(r.fps, 1),
            "utilization": round(busy / r.latency, 3),
        })
    return rows


def fig14_energy_efficiency():
    """Energy-efficiency ratios (ours / counterpart) per model x <W:I>."""
    table = energy_table()
    rows = []
    for m in MODELS:
        for cfg in WI_CONFIGS:
            row = {"model": m, "W:I": f"<{cfg[0]}:{cfg[1]}>"}
            for c in COUNTERPARTS:
                row[c.name] = round(table[(m, cfg, c.name)], 2)
            rows.append(row)
    return rows


def fig15_speedup():
    """Per-area speedup (ours / counterpart) per model x <W:I>."""
    table = speedup_table()
    rows = []
    for m in MODELS:
        for cfg in WI_CONFIGS:
            row = {"model": m, "W:I": f"<{cfg[0]}:{cfg[1]}>"}
            for c in COUNTERPARTS:
                row[c.name] = round(table[(m, cfg, c.name)], 2)
            rows.append(row)
    return rows


def table3_comparison():
    """Throughput / capacity / area of all accelerators (Table 3)."""
    g = Geometry()
    ours = simulate_model("resnet50")
    rows = [{
        "accelerator": c.name, "technology": c.technology,
        "fps": c.fps_t3, "capacity_MB": 64, "area_mm2": c.area_mm2,
        "fps_per_mm2": round(c.fps_t3 / c.area_mm2, 3),
    } for c in COUNTERPARTS]
    rows.append({
        "accelerator": "Proposed", "technology": "NAND-SPIN",
        "fps": round(ours.fps, 1), "capacity_MB": g.capacity_mb,
        "area_mm2": round(chip_area_mm2(g), 1),
        "fps_per_mm2": round(ours.fps / chip_area_mm2(g), 3),
    })
    return rows


def fig16_breakdown():
    """Latency and energy breakdown for ResNet50 (Fig. 16)."""
    r = simulate_model("resnet50")
    rows = []
    for phase in r.phases:
        rows.append({
            "phase": phase,
            "latency_frac": round(r.latency_breakdown[phase], 3),
            "energy_frac": round(r.energy_breakdown[phase], 3),
        })
    return rows


def fig17_area_overhead():
    """Add-on area breakdown (Fig. 17)."""
    split = add_on_area_mm2(Geometry())
    total = sum(split.values())
    return [{"component": k, "area_mm2": round(v, 2),
             "fraction": round(v / total, 3)} for k, v in split.items()]


def paper_claims_check():
    """Headline §5.3 claims vs what this reproduction produces."""
    sp = speedup_table()
    en = energy_table()

    def avg(table, name):
        vals = [v for (m, c, n), v in table.items() if n == name]
        return sum(vals) / len(vals)

    ours = simulate_model("resnet50")
    rows = [
        {"claim": "throughput_fps", "paper": PAPER_CLAIMS["throughput_fps"],
         "ours": round(ours.fps, 1)},
        {"claim": "area_mm2", "paper": PAPER_CLAIMS["area_mm2"],
         "ours": round(chip_area_mm2(Geometry()), 1)},
        {"claim": "speedup_vs_dram", "paper": 6.3, "ours": round(avg(sp, "DRISA"), 2)},
        {"claim": "speedup_vs_stt", "paper": 2.6, "ours": round(avg(sp, "STT-CiM"), 2)},
        {"claim": "speedup_vs_reram", "paper": 13.5, "ours": round(avg(sp, "PRIME"), 2)},
        {"claim": "speedup_vs_sot", "paper": 5.1, "ours": round(avg(sp, "IMCE"), 2)},
        {"claim": "energy_vs_dram", "paper": 2.3, "ours": round(avg(en, "DRISA"), 2)},
        {"claim": "energy_vs_stt", "paper": 1.4, "ours": round(avg(en, "STT-CiM"), 2)},
        {"claim": "energy_vs_reram", "paper": 12.3, "ours": round(avg(en, "PRIME"), 2)},
        {"claim": "energy_vs_sot", "paper": 2.6, "ours": round(avg(en, "IMCE"), 2)},
    ]
    return rows
