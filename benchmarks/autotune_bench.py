"""Autotuner regret benchmark: picked vs exhaustive-best backend per GEMM.

For every swept (m, k, n, <W:I>) point, each XLA backend runs once through
the real prepacked dispatch (``int_matmul_prepacked``) and its wall-clock
is memoized. The sweep then asks :func:`repro.pim.autotune.decide_gemm`
for its pick two ways and scores both against the exhaustive best of the
same memoized timings:

  ``pick``/``regret``            mode="measure" — the deployment default
                                 when measurement is affordable; the
                                 injected measurer replays the memoized
                                 times, so the regret is exact and the CI
                                 gate (≤15% on ≥90% of points, aggregate
                                 strictly better than the fixed default)
                                 cannot flake on timer jitter.
  ``pick_cost``/``regret_cost``  mode="cost" — the analytic NAND-SPIN
                                 ranking alone, the honest column: how
                                 good the cost model is when measuring is
                                 off the table (fresh shapes at serve
                                 time, cross-device caches).

``fixed_ms`` is the backend a constant would have chosen — "int-direct",
the repo-wide ``PIMQuantConfig`` default — quantifying what the autotuner
buys over the best single setting. The pallas backend is excluded from the
sweep on CPU: interpret mode measures the Python loop body, not a
contender (the analytic ranker knows this too — see ``autotune._RATES``).

``benchmarks.run --only autotune`` writes the rows to BENCH_kernels.json
under ``autotune_regret``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.bitserial import int_matmul_prepacked
from repro.core.packed import prepack
from repro.pim import autotune as at

SHAPES = [(4, 2048, 2048), (8, 4096, 1024), (64, 8192, 512),
          (256, 2048, 256), (1024, 512, 1024)]
SMOKE_SHAPES = [(4, 512, 512), (32, 1024, 256), (128, 256, 512)]
BITS = [(2, 2), (4, 4), (8, 8)]
FIXED = "int-direct"            # the PIMQuantConfig default backend


def _bench(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))      # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def _measure_backends(m, k, n, a_bits, w_bits, backends, iters):
    """One wall-clock per backend through the real prepacked dispatch."""
    key = jax.random.PRNGKey(0)
    qa = jax.random.randint(key, (m, k), 0, 2 ** a_bits, jnp.int32)
    pk = prepack(jax.random.normal(jax.random.fold_in(key, 1), (k, n)),
                 w_bits)
    times = {}
    for be in backends:
        fn = jax.jit(lambda a, w, b=be: int_matmul_prepacked(
            a, w, a_bits, backend=b))
        times[be] = _bench(fn, qa, pk, iters=iters)
    return times


def autotune_regret(smoke: bool = False):
    backends = at.XLA_BACKENDS
    shapes = SMOKE_SHAPES if smoke else SHAPES
    iters = 2 if smoke else 3
    rows = []
    for (m, k, n) in shapes:
        for (wb, ab) in BITS:
            times = _measure_backends(m, k, n, ab, wb, backends, iters)
            best = min(times, key=times.get)
            replay = lambda d, *a: times[d.backend]
            d_cost = at.decide_gemm(m, k, n, ab, wb, backends=backends,
                                    mode="cost", hlo_tiebreak=False)
            d_meas = at.decide_gemm(m, k, n, ab, wb, backends=backends,
                                    mode="measure", measure=replay,
                                    hlo_tiebreak=False)
            ms = {be: times[be] * 1e3 for be in backends}
            rows.append({
                "m_k_n": f"{m}x{k}x{n}", "W:I": f"<{wb}:{ab}>",
                "popcount_ms": round(ms["popcount"], 3),
                "mxu_plane_ms": round(ms["mxu-plane"], 3),
                "int_direct_ms": round(ms["int-direct"], 3),
                "best": best, "best_ms": round(ms[best], 3),
                "fixed": FIXED, "fixed_ms": round(ms[FIXED], 3),
                "pick": d_meas.backend,
                "picked_ms": round(ms[d_meas.backend], 3),
                "regret": round(ms[d_meas.backend] / ms[best] - 1.0, 4),
                "pick_cost": d_cost.backend,
                "regret_cost": round(ms[d_cost.backend] / ms[best] - 1.0, 4),
            })
    return rows
