"""Accuracy-vs-BER frontier for the NAND-SPIN fault model (DESIGN.md §7).

The paper's architecture stores every quantized weight bit as one MTJ
state; STT-MRAM's stochastic write/retention physics makes raw bit error
rates a first-order design input. This benchmark sweeps the programming
BER over the paper's AlexNet workload and measures what the mitigation
hierarchy (MSB-plane majority voting + column-checksum detection +
spare-column remap, ``repro.pim.faults``) buys back:

  * ``acc_free``      — clean quantized top-1 agreement with the float
                        reference (the quantization ceiling at that ⟨W:I⟩).
  * ``acc_faulty``    — same model programmed through the bare fault
                        channel, no mitigation.
  * ``acc_protected`` — programmed through the same faults (same PRNG key:
                        identical error pattern) with the hierarchy armed.
  * ``gap_recovered`` — (protected − faulty) / (free − faulty), the
                        fraction of the fault-induced accuracy gap the
                        mitigation recovers (1.0 when there is no gap).

``fault_overhead`` prices what that protection costs: the storage /
sense / programming redundancy factors charged by ``pim.cost_model`` and
the extra die area from ``pim.area.ecc_area_mm2`` — the frontier's other
axis. ``benchmarks.run --only fault`` renders both tables and writes
``BENCH_faults.json``; ``--smoke`` shrinks the sweep to CI scale with the
same artifact shape.
"""
from __future__ import annotations

import math

import jax
import numpy as np

from repro.core import PIMQuantConfig
from repro.models.cnn import alexnet
from repro.models.cnn.layers import prepack_params
from repro.pim import FaultConfig, ecc_area_mm2, redundancy_factors
from repro.pim.hierarchy import Geometry

_IMAGE, _CLASSES, _SEED = 64, 16, 11
_PRECISIONS = ["<4:4>", "<8:8>"]


def _protected(w_bits: int, ber: float) -> FaultConfig:
    """The benchmark's mitigation point, tuned per precision.

    4-bit codes shrug off unvoted-LSB flips (max perturbation 3 of 15
    levels), so voting the top half of the planes suffices. 8-bit codes do
    not: flips in unvoted planes that cancel inside a column sum evade the
    checksum forever (the documented quadratic escape), and at 255 levels
    the surviving corruption costs real accuracy — the 8-bit point votes
    every plane. Both arm the checksum with 112 spare columns per
    128-column subarray (test-and-repair regime: at these BERs nearly every
    column is flagged, so the spare fraction bounds the repaired share)."""
    protect = w_bits if w_bits > 4 else math.ceil(w_bits / 2)
    return FaultConfig(write_ber=ber, seed=_SEED,
                       protect_msb=protect, vote_copies=3,
                       checksum=True, spare_cols=112)


def _top1(tree, cfg, batch):
    fn = jax.jit(lambda p, x: alexnet.apply(p, x, cfg=cfg))
    return np.asarray(fn(tree, batch)).argmax(-1)


def fault_frontier(smoke: bool = False):
    """Top-1-vs-float accuracy across (precision, BER) with/without ECC."""
    bers = [1e-3, 1e-2] if smoke else [1e-3, 3e-3, 1e-2, 3e-2]
    n_images = 16 if smoke else 32
    key = jax.random.PRNGKey(0)
    params = alexnet.init(key, num_classes=_CLASSES, image=_IMAGE)
    batch = np.asarray(
        jax.random.normal(jax.random.fold_in(key, 1),
                          (n_images, _IMAGE, _IMAGE, 3)), np.float32)
    ref = _top1(params, None, batch)

    rows = []
    for precision in _PRECISIONS:
        w_bits, a_bits = (int(b) for b in precision.strip("<>").split(":"))
        cfg = PIMQuantConfig(w_bits=w_bits, a_bits=a_bits,
                             backend="int-direct")
        clean = prepack_params(params, cfg)
        acc_free = float((_top1(clean, cfg, batch) == ref).mean())
        for ber in bers:
            bare = FaultConfig(write_ber=ber, seed=_SEED)
            faulty = prepack_params(params, cfg, faults=bare)
            prot = prepack_params(params, cfg,
                                  faults=_protected(w_bits, ber))
            acc_faulty = float((_top1(faulty, cfg, batch) == ref).mean())
            acc_prot = float((_top1(prot, cfg, batch) == ref).mean())
            gap = acc_free - acc_faulty
            recovered = (1.0 if gap <= 1e-9 else
                         max(0.0, min(1.0, (acc_prot - acc_faulty) / gap)))
            rows.append({
                "model": "alexnet", "precision": precision, "ber": ber,
                "acc_free": round(acc_free, 4),
                "acc_faulty": round(acc_faulty, 4),
                "acc_protected": round(acc_prot, 4),
                "gap_recovered": round(recovered, 4),
            })
    return rows


def fault_overhead(smoke: bool = False):
    """What the protection point costs: redundancy factors + die area."""
    del smoke  # analytical: already CI-scale
    g = Geometry()
    base_area = None
    rows = []
    for precision in _PRECISIONS:
        w_bits = int(precision.strip("<>").split(":")[0])
        fc = _protected(w_bits, ber=0.0)
        red = redundancy_factors(fc, w_bits, g.cols)
        if base_area is None:
            from repro.pim import chip_area_mm2
            base_area = chip_area_mm2(g)
        extra = ecc_area_mm2(g, fc, w_bits)
        rows.append({
            "precision": precision,
            "protect_msb": fc.protect_msb, "vote_copies": fc.vote_copies,
            "spare_cols": fc.spare_cols,
            "storage_x": round(red["storage"], 3),
            "rowops_x": round(red["rowops"], 3),
            "program_x": round(red["program"], 3),
            "ecc_area_mm2": round(extra, 3),
            "area_overhead_pct": round(100.0 * extra / base_area, 2),
        })
    return rows
