"""Quickstart: the paper's technique in five minutes.

1. Run a quantized bit-serial matmul (Eq. 1) three ways and check they agree.
2. Run AlexNet inference with PIM-quantized conv layers.
3. Price that inference on the NAND-SPIN architecture simulator.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import PIMQuantConfig, quantized_matmul
from repro.models.cnn import alexnet
from repro.pim.simulator import simulate_model


def main():
    key = jax.random.PRNGKey(0)

    # -- 1. Eq. 1: I*W = sum 2^(n+m) bitcount(AND(plane_n, plane_m)) --------
    a = jax.random.normal(key, (4, 256))
    w = jax.random.normal(jax.random.fold_in(key, 1), (256, 8))
    dense = a @ w
    for backend in ("popcount", "mxu-plane", "pallas"):
        y = quantized_matmul(a, w, a_bits=8, w_bits=8, backend=backend)
        err = float(jnp.abs(y - dense).max() / jnp.abs(dense).max())
        print(f"backend={backend:10s} max rel err vs dense fp32: {err:.4f}")

    # -- 2. AlexNet forward with PIM-quantized convolutions -----------------
    cfg = PIMQuantConfig(w_bits=8, a_bits=8, backend="int-direct")
    params = alexnet.init(jax.random.fold_in(key, 2), image=64)
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 64, 64, 3))
    logits = alexnet.apply(params, x, cfg=cfg)
    print(f"\nAlexNet<8:8> logits shape {logits.shape}, "
          f"finite={bool(jnp.isfinite(logits).all())}")

    # -- 3. Price ResNet50 on the NAND-SPIN simulator -----------------------
    r = simulate_model("resnet50")
    print(f"\nNAND-SPIN 64MB/128b: ResNet50 {r.fps:.1f} fps "
          f"(paper Table 3: 80.6), {r.energy * 1e3:.2f} mJ/frame")
    print("latency breakdown:", {k: round(v, 3) for k, v in
                                 r.latency_breakdown.items()})


if __name__ == "__main__":
    main()
