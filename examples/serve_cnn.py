"""Batched CNN serving through the vision engine (DESIGN.md §6).

Mixed-precision traffic against one AlexNet deployment: requests carry their
own ⟨W:I⟩ precision, the engine micro-batches each (model, precision)
cohort into power-of-two buckets, prepacks the weights exactly once per
cohort (the paper's program-subarrays-once step) and serves every bucket
through the prepacked bit-serial conv path.

  PYTHONPATH=src python examples/serve_cnn.py

  # mesh-sharded: image batches on "data" (chips), conv output channels on
  # "model" (banks) — force a multi-device host before any jax import
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/serve_cnn.py
"""
import jax
import numpy as np

from repro.models.cnn import alexnet
from repro.serving import VisionEngine, VisionRequest


def main():
    image, classes = 64, 16
    params = alexnet.init(jax.random.PRNGKey(0), image=image,
                          num_classes=classes)
    mesh = None
    if len(jax.devices()) > 1:
        from repro.launch.mesh import make_serve_mesh

        mesh = make_serve_mesh(2)
        print(f"serving on mesh {dict(mesh.shape)}")
    eng = VisionEngine({"alexnet": params}, backend="int-direct",
                       max_batch=8, mesh=mesh)

    rng = np.random.default_rng(0)
    precisions = ["<8:8>", "<8:8>", "<8:8>", None]  # None = float reference
    for rid in range(12):
        eng.submit(VisionRequest(
            rid=rid, image=rng.standard_normal((image, image, 3)),
            model="alexnet", precision=precisions[rid % len(precisions)]))

    done = eng.run()
    for c in sorted(done, key=lambda c: c.rid):
        print(f"req {c.rid:2d}: top1={c.top1:2d}  "
              f"logit[top1]={c.logits[c.top1]:+.4f}  bucket={c.batch}")
    print(f"\n{len(done)} completions; compiled forwards: "
          f"{sorted((m, str(p), b) for m, p, b in eng._fwd)}")


if __name__ == "__main__":
    main()
