"""Batched serving example: continuous batching with slot recycling.

Submits more requests than decode slots; the engine prefills into freed
slots while other sequences keep decoding (no global drain).

  PYTHONPATH=src python examples/serve_lm.py

Multi-device: when more than one accelerator is visible the example builds
a ("data", "model") serving mesh — decode slots (the paper's chips) shard
on "data", weight columns (the banks) on "model" (DESIGN.md §5). A
CPU-only box can fake the devices; XLA reads this flag at backend init, so
it must be set before any jax import:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_serve_mesh
from repro.models.lm import init as model_init
from repro.models.lm.model import cast_params
from repro.serving import Request, SamplerConfig, ServeEngine


def main():
    cfg = get_config("qwen3-0.6b").model.reduced()
    params = cast_params(model_init(cfg, jax.random.PRNGKey(0)),
                         jnp.dtype(cfg.dtype))
    mesh = None
    if len(jax.devices()) > 1:
        # 2-way bank/tensor parallelism when the device count allows it;
        # the remaining devices shard the 4 decode slots.
        model_par = 2 if len(jax.devices()) % 2 == 0 else 1
        mesh = make_serve_mesh(model_par)
        print(f"mesh: {dict(mesh.shape)}")
    eng = ServeEngine(cfg, params, max_batch=4, max_len=96,
                      sampler=SamplerConfig(temperature=0.8, top_k=40),
                      mesh=mesh)
    rng = np.random.default_rng(7)
    n_req = 10
    t0 = time.time()
    for rid in range(n_req):
        L = int(rng.integers(4, 24))
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, size=L).astype(np.int32),
                           max_new_tokens=int(rng.integers(8, 24))))
    done = eng.run()
    dt = time.time() - t0
    total = sum(len(c.tokens) for c in done)
    for c in sorted(done, key=lambda c: c.rid)[:4]:
        print(f"req {c.rid}: generated {len(c.tokens)} tokens: {c.tokens[:10]}")
    print(f"\n{len(done)}/{n_req} requests complete, {total} new tokens "
          f"in {dt:.1f}s ({total/dt:.1f} tok/s) with 4 decode slots")


if __name__ == "__main__":
    main()
