"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on synthetic structured text, with checkpoints + restart.

This is the (b) end-to-end deliverable at CPU scale; the identical entry
point (repro.launch.train) runs the full assigned configs on a real fleet.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="llama3.2-3b")
    args = ap.parse_args()

    # ~100M params: reduced llama3.2 with wider dims than the smoke config.
    sys.argv = [
        "train", "--arch", args.arch, "--reduced",
        "--steps", str(args.steps), "--batch", "16", "--seq", "256",
        "--lr", "6e-4", "--ckpt-dir", "/tmp/repro_train_lm",
        "--log-every", "20",
    ]
    train_main()


if __name__ == "__main__":
    main()
