"""The paper's own pipeline end-to-end: quantized CNN inference through the
bit-serial PIM path, then device-level pricing of the same network.

Sweeps <W:I> precision like Figs. 14-15 and reports (a) numerical accuracy
deltas of the bit-serial path vs fp32, (b) simulated fps/energy on the
NAND-SPIN architecture.

  PYTHONPATH=src python examples/pim_cnn_inference.py
"""
import jax
import jax.numpy as jnp

from repro.core import PIMQuantConfig
from repro.models.cnn import resnet
from repro.pim.simulator import simulate_model


def main():
    key = jax.random.PRNGKey(0)
    image = 64  # reduced resolution for CPU
    params = resnet.init(key, image=image)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, image, image, 3))

    ref = resnet.apply(params, x, cfg=None)  # fp32 reference
    print(f"{'W:I':8s} {'top1 agree':>10s} {'max|dlogit|':>12s} "
          f"{'sim fps':>8s} {'mJ/frame':>9s}")
    for bits in (2, 4, 8):
        cfg = PIMQuantConfig(w_bits=bits, a_bits=bits, backend="int-direct")
        # Deployment mode: weights quantize+pack exactly once (the paper
        # programs subarrays once); apply() then only quantizes activations.
        packed = resnet.prepack(params, cfg)
        y = resnet.apply(packed, x, cfg=cfg)
        agree = float((y.argmax(-1) == ref.argmax(-1)).mean())
        dmax = float(jnp.abs(y - ref).max())
        r = simulate_model("resnet50", ab=bits, wb=bits)
        print(f"<{bits}:{bits}>   {agree:10.2f} {dmax:12.4f} "
              f"{r.fps:8.1f} {r.energy * 1e3:9.2f}")

    print("\nInterpretation: lower precision -> higher simulated fps "
          "(fewer bit-plane pairs), at growing numerical deviation — the "
          "paper's Figs. 14-15 trade-off.")


if __name__ == "__main__":
    main()
