"""HotPath declarations: what a serving engine promises about its compiled
programs, in a form the rule registry can check.

An engine (ServeEngine, VisionEngine) exposes ``hot_paths()`` returning
:class:`HotPath` objects — each one a named family of jitted programs plus
a :class:`Budget` declaring the invariants its hot loop depends on
(collective budget, donation aliasing, dtype discipline, ...). Engines
register themselves at construction and unregister in ``close()``; the
CLI (``python -m repro.analysis lint``) and the CI gate lint every live
registration, and the test suites call :func:`lint_hot_paths` directly on
a single engine.

Programs lower and compile lazily, under the hot path's own context
(``engine._activate`` — the mesh/layout scope the real dispatch uses), so
what the rules inspect is byte-for-byte the executable the hot loop runs.
"""
from __future__ import annotations

import contextlib
import dataclasses
import weakref


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule violation, attributed to a program of a hot path."""

    program: str          # "lm.decode:n=8" — hot path name + program label
    rule: str             # registry name, e.g. "collective-budget"
    message: str

    def __str__(self):
        return f"{self.program}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Budget:
    """Per-hot-path invariant declaration the rules check against.

    collectives       max textual count per compiled program for each
                      collective kind (missing kind = unconstrained).
    max_gather_bytes  largest all-gather result allowed (None = no bound;
                      0 = fully replicated, no gathers at all). The 16 KiB
                      serving default separates KB-scale control
                      broadcasts from KV-cache/weight-sized resharding.
    scan_flat         with >1 program in the family, textual collective
                      counts must be identical across all of them (the
                      drain-length-flatness invariant of DESIGN.md §5).
    donate            argnums whose every leaf must be aliased in the
                      compiled executable (donation actually honored, not
                      silently copied). Donations that exist only to free
                      the input buffer (vision's image batch) stay out.
    compute_dtype     "bf16" forbids f32 dot/convolution results in the
                      compiled program; None disables the upcast check.
    allow_f64/allow_host_sync/check_weak_scalars  rule switches.
    m_hint            GEMM row count of this deployment (decode slot count
                      / bucket rows) — the tile-legality rule checks
                      autotuner tile requests divide against it.
    pallas_ok         False when the context shards a mesh (pallas_call
                      has no GSPMD rule; a pallas TuneDecision would
                      silently all-gather every step).
    """

    collectives: tuple = (("all-to-all", 0),)
    max_gather_bytes: int | None = 16384
    scan_flat: bool = True
    donate: tuple = ()
    compute_dtype: str | None = None
    allow_f64: bool = False
    allow_host_sync: bool = False
    check_weak_scalars: bool = True
    m_hint: int | None = None
    pallas_ok: bool = True


class Program:
    """One jitted program of a hot path: a label, the jitted callable and
    example args. Lowers/compiles lazily (once) under the owning hot
    path's context; test harnesses may inject ``text=`` directly to unit-
    test rule logic without compiling."""

    def __init__(self, label, fn, args, kwargs=None, text=None):
        self.label = label
        self.fn = fn
        self.args = tuple(args)
        self.kwargs = dict(kwargs or {})
        self._text = text
        self._compiled = None
        self._jaxpr = None
        self._context = contextlib.nullcontext

    def compiled(self):
        if self._compiled is None:
            with self._context():
                self._compiled = self.fn.lower(*self.args,
                                               **self.kwargs).compile()
        return self._compiled

    def compiled_text(self) -> str:
        if self._text is None:
            self._text = self.compiled().as_text()
        return self._text

    def kept_var_idx(self, total: int) -> set:
        """Flat-arg indices the executable kept as parameters (jit prunes
        unused args, shifting parameter numbering). Falls back to
        all-kept for injected-text programs or if jax's internal moves."""
        if self.fn is None:
            return set(range(total))
        ex = getattr(self.compiled(), "_executable", None)
        kept = getattr(ex, "_kept_var_idx", None)
        return set(range(total)) if kept is None else set(kept)

    def jaxpr(self):
        if self._jaxpr is None:
            import jax

            with self._context():
                self._jaxpr = jax.make_jaxpr(self.fn)(*self.args,
                                                      **self.kwargs)
        return self._jaxpr


@dataclasses.dataclass
class HotPath:
    """A named family of programs sharing one budget and one context."""

    name: str                       # "lm.decode", "cnn.fwd[mini,<4:4>]"
    workload: str                   # "lm" | "cnn" | "gateway"
    budget: Budget
    programs: list
    context: object = None          # zero-arg callable -> context manager

    def __post_init__(self):
        ctx = self.context or contextlib.nullcontext
        for p in self.programs:
            p._context = ctx

    def lint(self, rules=None) -> list[Violation]:
        from repro.analysis import rules as _rules

        return _rules.run_rules(self, names=rules)


# -- process-wide registration ----------------------------------------------
#
# Engines register at construction and unregister in close(); weakrefs so
# a dropped engine never pins its packed tree (or blocks GC) just because
# nobody linted it.

_PROVIDERS: "weakref.WeakSet" = weakref.WeakSet()


def register(provider) -> None:
    """Register an object exposing ``hot_paths() -> list[HotPath]``."""
    _PROVIDERS.add(provider)


def unregister(provider) -> None:
    _PROVIDERS.discard(provider)


def registered() -> list:
    return list(_PROVIDERS)


def iter_hot_paths(workload=None):
    for prov in list(_PROVIDERS):
        for hp in prov.hot_paths():
            if workload is None or hp.workload == workload:
                yield hp


def lint_hot_paths(hot_paths, rules=None) -> list[Violation]:
    """Run the rule registry over hot paths; returns all violations."""
    out = []
    for hp in hot_paths:
        out += hp.lint(rules=rules)
    return out


def lint_registered(workload=None, rules=None) -> list[Violation]:
    return lint_hot_paths(iter_hot_paths(workload), rules=rules)


def format_report(violations) -> str:
    if not violations:
        return "OK: no hot-path invariant violations"
    lines = [f"{len(violations)} hot-path invariant violation(s):"]
    lines += [f"  {v}" for v in violations]
    return "\n".join(lines)
