"""Shared HLO/jaxpr text inspection helpers.

One home for the regexes that every hot-path invariant rests on: the
serving tests (tests/test_serve_sharded.py, tests/test_vision_engine.py),
the rule registry (repro.analysis.rules) and the CI lint gate all call
these — so the test suite and the ``python -m repro.analysis lint`` gate
can never drift apart on what counts as a collective, an alias, or a
host round-trip.

Everything here is pure text/jaxpr analysis: no compilation, no device
work. Callers hand in ``fn.lower(*args).compile().as_text()`` dumps (see
``compiled_text``) or jaxprs from ``jax.make_jaxpr``.
"""
from __future__ import annotations

import re

# Mirrors roofline.hlo_cost._DTYPE_BYTES; kept tiny and local so text
# helpers stay importable without jax.
DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
               "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
               "u64": 8}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "all-to-all",
                  "collective-permute")

# Host-callback custom-call targets XLA emits for jax.pure_callback /
# jax.debug.callback / io_callback (CPU and GPU spellings).
_CALLBACK_TARGETS = ("xla_python_cpu_callback", "xla_python_gpu_callback",
                     "xla_ffi_python_cpu_callback",
                     "xla_ffi_python_gpu_callback")


def gather_sizes(txt: str) -> list[int]:
    """Byte size of every all-gather result in an HLO text dump."""
    out = []
    for m in re.finditer(r"= (\w+)\[([\d,]*)\][^a-zA-Z]*all-gather", txt):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        out.append(n * DTYPE_BYTES.get(m.group(1), 4))
    return out


def collective_counts(txt: str) -> dict[str, int]:
    """Textual (not trip-count-multiplied) collective op counts.

    Textual counts are the scan-length-flatness currency: a decode program
    whose per-step body gathers once shows ``n`` all-gathers at drain
    length ``n`` after unrolling — flat textual counts across the pow2
    drain family prove the collectives live outside the scan body.
    """
    return {op: len(re.findall(r"= \S+ " + op.replace("-", "[-]") + r"\(",
                               txt))
            for op in COLLECTIVE_OPS}


def input_output_aliases(txt: str) -> set[int]:
    """Parameter numbers the compiled module aliases into its outputs.

    jax requests (may-)aliasing for every donated buffer it can pair with
    an output; donations it cannot use are silently dropped from the
    ``input_output_alias={...}`` header — so a donated argnum whose
    parameters are absent here fell back to a copy.
    """
    i = txt.find("input_output_alias=")
    if i < 0:
        return set()
    j = txt.index("{", i)
    depth, end = 0, -1
    for k in range(j, len(txt)):
        if txt[k] == "{":
            depth += 1
        elif txt[k] == "}":
            depth -= 1
            if depth == 0:
                end = k
                break
    if end < 0:
        return set()
    return {int(p) for p in re.findall(r":\s*\((\d+),", txt[j:end + 1])}


def entry_param_count(txt: str) -> int | None:
    """Number of parameters of the ENTRY computation (None if unparsable).

    Needed to detect dropped/pruned arguments: jit prunes unused args from
    the executable, which would silently shift the param->argnum mapping
    the donation rule depends on.
    """
    m = re.search(r"^ENTRY [^(]*\((.*)\) -> ", txt, re.M)
    if m is None:
        return None
    sig = m.group(1).strip()
    if not sig:
        return 0
    depth, count = 0, 1
    for ch in sig:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            count += 1
    return count


def host_callback_sites(txt: str) -> list[str]:
    """Host round-trips in a compiled dump: python-callback custom calls,
    infeed/outfeed, and host-transfer send/recv."""
    out = []
    for tgt in _CALLBACK_TARGETS:
        out += [f'custom-call {tgt}'] * txt.count(
            f'custom_call_target="{tgt}"')
    for op in ("infeed", "outfeed"):
        out += [op] * len(re.findall(r"= \S+ " + op + r"\(", txt))
    out += ["host send/recv"] * len(
        re.findall(r"= \S+ (?:send|recv)\([^)]*\), [^\n]*is_host_transfer="
                   r"true", txt))
    return out


def has_f64(txt: str) -> bool:
    return "f64[" in txt


def f32_matmul_eqns(jaxpr) -> list[str]:
    """f32-result matmul/conv primitives in the trace — the upcasts a
    declared-bf16 region must not contain. Checked on the jaxpr, not the
    compiled HLO: XLA CPU legitimately *accumulates* bf16 matmuls in f32,
    but a program whose traced dot operates on f32 avals means user code
    upcast the operands."""
    import numpy as np

    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name not in ("dot_general",
                                      "conv_general_dilated"):
            continue
        aval = getattr(eqn.outvars[0], "aval", None)
        if aval is not None and getattr(aval, "dtype", None) == np.float32:
            out.append(eqn.primitive.name)
    return out


# -- jaxpr-side helpers ------------------------------------------------------

def iter_eqns(jaxpr):
    """All equations of a (closed) jaxpr, recursing into sub-jaxprs
    (pjit/scan/while/cond bodies)."""
    jx = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jx.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    yield from iter_eqns(sub)


def callback_primitives(jaxpr) -> list[str]:
    """Names of callback/infeed/outfeed primitives anywhere in the trace."""
    out = []
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if "callback" in name or name in ("infeed", "outfeed"):
            out.append(name)
    return out


def plane_float_converts(jaxpr) -> list[str]:
    """convert_element_type sites that move a packed uint32 plane (>= 2-d)
    into a float dtype — bit planes are opaque words; any float view of
    them is a layout bug.  1-d/scalar u32 (PRNG keys, counters) pass."""
    import numpy as np

    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        (inv,), (outv,) = eqn.invars, eqn.outvars
        ia, oa = getattr(inv, "aval", None), getattr(outv, "aval", None)
        if ia is None or oa is None or not hasattr(ia, "dtype"):
            continue
        if (ia.dtype == np.uint32 and ia.ndim >= 2
                and np.issubdtype(oa.dtype, np.floating)):
            out.append(f"convert {ia.str_short()} -> {oa.str_short()}")
    return out


def lowered_text(fn, *args, **kwargs) -> str:
    """Stable-lowering fingerprint (pre-optimization StableHLO text)."""
    return fn.lower(*args, **kwargs).as_text()


def compiled_text(fn, *args, **kwargs) -> str:
    """Optimized HLO of the compiled executable — what the rules inspect."""
    return fn.lower(*args, **kwargs).compile().as_text()
