"""Hot-path static analysis: jaxpr/HLO invariant linting (DESIGN.md §10).

Engines declare their jitted programs and budgets via the
:class:`HotPath` API; the rule registry (``repro.analysis.rules``) checks
collective budgets, donation aliasing, dtype discipline, host-sync
freedom, recompile hazards and tile legality against the *compiled*
executables. ``python -m repro.analysis lint`` gates every registered
program in CI at 1- and 8-device topologies; the serving test suites
call the same rule implementations directly.
"""
from repro.analysis import hlo, threads
from repro.analysis.hotpath import (Budget, HotPath, Program, Violation,
                                    format_report, iter_hot_paths,
                                    lint_hot_paths, lint_registered,
                                    register, registered, unregister)
from repro.analysis.rules import RULES, run_rules

__all__ = ["Budget", "HotPath", "Program", "Violation", "RULES", "hlo",
           "threads", "format_report", "iter_hot_paths", "lint_hot_paths",
           "lint_registered", "register", "registered", "unregister",
           "run_rules"]
