"""Thread-ownership lint for the asyncio gateway.

serving/gateway.py splits work across two thread domains (its module
docstring states the ownership rule): the **asyncio event loop** owns the
bounded queues, handles and telemetry; the **worker threads** own the
engines — every engine-state mutation (submit/step/cancel/redeploy/...)
must happen on a worker, reached only through the queue. This checker
enforces that statically: it walks the gateway's AST, builds the
``self.method()`` call graph, computes which methods are reachable from
the event-loop entry points, and flags any engine mutation — a call to a
non-read-only engine method, or an attribute store on an engine — inside
that reachable set.

The thread boundary itself is modelled precisely: passing a bound method
as a *value* (``Thread(target=self._lm_worker)``, ``self._guard(fn)``)
creates no call edge, and function bodies nested inside a method (the
worker closures ``_guard`` builds) are excluded from their enclosing
method's scan — deferred execution happens on whichever thread runs the
closure, not the caller's.
"""
from __future__ import annotations

import ast

from repro.analysis.hotpath import Violation

# Event-loop-side entry points of the Gateway class: public API awaited /
# called from asyncio, plus the loop-side callbacks they use. __init__ is
# excluded — it runs before any worker thread exists.
LOOP_ROOTS = ("submit_lm", "submit_vision", "start", "stop", "drain",
              "stats", "__aenter__", "__aexit__")

# Engine members the event loop may *call*: read-only validation/telemetry
# with no engine-state writes. Everything else (submit, step, cancel,
# redeploy, degrade_cohort, run, snapshot, restore, close, ...) mutates.
ENGINE_READONLY_CALLS = ("validate", "n_free_slots")

ENGINE_ATTRS = ("_lm", "_vision")


def _self_attr(node):
    """'name' for a ``self.name`` expression, else None."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _engine_of(node):
    """'_lm' for a ``self._lm`` / ``self._lm.<x>`` chain root, else None."""
    n = node
    while isinstance(n, ast.Attribute):
        root = _self_attr(n)
        if root in ENGINE_ATTRS:
            return root
        n = n.value
    return None


def _iter_body(node):
    """Statements of a method body, skipping nested function/lambda bodies
    (they execute on whichever thread calls them, not here)."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        yield child
        yield from _iter_body(child)


def check_source(source: str, class_name: str = "Gateway",
                 loop_roots=LOOP_ROOTS,
                 engine_attrs=ENGINE_ATTRS,
                 readonly_calls=ENGINE_READONLY_CALLS,
                 filename: str = "gateway.py"):
    """Lint one module's source; returns a list of Violations."""
    tree = ast.parse(source)
    cls = next((n for n in ast.walk(tree)
                if isinstance(n, ast.ClassDef) and n.name == class_name),
               None)
    if cls is None:
        return [Violation(f"{filename}", "thread-ownership",
                          f"class {class_name} not found")]

    methods = {n.name: n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}

    # Direct self.method() call edges, nested closures excluded
    # (_iter_body yields every descendant node outside nested functions).
    edges: dict = {}
    for name, node in methods.items():
        edges[name] = set()
        for sub in _iter_body(node):
            if isinstance(sub, ast.Call):
                callee = _self_attr(sub.func)
                if callee in methods:
                    edges[name].add(callee)

    # Reachability from the event-loop roots.
    reachable, frontier = set(), [r for r in loop_roots if r in methods]
    while frontier:
        m = frontier.pop()
        if m in reachable:
            continue
        reachable.add(m)
        frontier += list(edges.get(m, ()))

    out = []
    for name in sorted(reachable):
        node = methods[name]
        for sub in _iter_body(node):
            # engine method calls
            if isinstance(sub, ast.Call):
                func = sub.func
                if isinstance(func, ast.Attribute) and \
                        _engine_of(func.value) in engine_attrs:
                    if func.attr not in readonly_calls:
                        out.append(Violation(
                            f"{filename}:{class_name}.{name}",
                            "thread-ownership",
                            f"line {sub.lineno}: engine call "
                            f".{func.attr}() reachable from the asyncio "
                            f"thread; engine mutations must go through "
                            f"the worker queue"))
            # engine attribute stores (incl. augmented assignment)
            targets = []
            if isinstance(sub, ast.Assign):
                targets = sub.targets
            elif isinstance(sub, (ast.AugAssign, ast.AnnAssign)):
                targets = [sub.target]
            for t in targets:
                if isinstance(t, ast.Attribute) and \
                        _engine_of(t.value) in engine_attrs:
                    out.append(Violation(
                        f"{filename}:{class_name}.{name}",
                        "thread-ownership",
                        f"line {sub.lineno}: engine attribute store "
                        f".{t.attr} = ... reachable from the asyncio "
                        f"thread"))
    return out


def check_gateway():
    """Lint the shipped serving/gateway.py module."""
    import inspect

    from repro.serving import gateway as gw

    return check_source(inspect.getsource(gw),
                        filename=gw.__file__.rsplit("/", 1)[-1])
