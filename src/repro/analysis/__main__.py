"""CLI lint gate: build the serving hot paths and run the rule registry.

  PYTHONPATH=src python -m repro.analysis lint --workload lm --mesh 4x2
  PYTHONPATH=src python -m repro.analysis lint --workload all --mesh 1x1

``--mesh AxB`` forces an A*B-device host topology *before jax imports*
(XLA reads --xla_force_host_platform_device_count at backend init) and
serves with B-way model parallelism — the same mesh shape the serving
launcher builds. ``1x1`` lints the mesh-free single-device programs.

Exit status 1 on any rule violation; the report names each offending
``<hotpath>:<program>`` and rule. CI runs this at 1 and 8 devices (the
lint-hotpath job) so every registered program is gated on both
topologies.
"""
import argparse
import os
import sys


def _parse_mesh(spec: str):
    try:
        data, model = (int(x) for x in spec.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh {spec!r}: want AxB, e.g. 4x2")
    if data < 1 or model < 1:
        raise SystemExit(f"--mesh {spec!r}: dims must be >= 1")
    return data, model


def _build_lm(mesh, max_batch):
    import jax

    from repro.core.pim_layers import PIMQuantConfig
    from repro.models.lm import ModelConfig, init
    from repro.serving import SamplerConfig, ServeEngine

    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=61, remat="none", dtype="float32",
                      pim=PIMQuantConfig(w_bits=4, a_bits=4,
                                         backend="int-direct"))
    params = init(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, max_batch=max_batch, max_len=64,
                       sampler=SamplerConfig(temperature=0.0), mesh=mesh)


def _build_moe_lm(mesh, max_batch):
    """MoE engine: packed expert banks (expert-parallel program family on
    a multi-device mesh — the dispatch a2a/combine budget, DESIGN.md §11)."""
    import jax

    from repro.core.pim_layers import PIMQuantConfig
    from repro.models.lm import ModelConfig, init
    from repro.models.lm.config import MoEConfig
    from repro.serving import SamplerConfig, ServeEngine

    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=61, remat="none", dtype="float32",
                      moe=MoEConfig(n_experts=4, top_k=2),
                      pim=PIMQuantConfig(w_bits=4, a_bits=4,
                                         backend="int-direct"))
    params = init(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, max_batch=max_batch, max_len=64,
                       sampler=SamplerConfig(temperature=0.0), mesh=mesh)


def _build_pipe_lm(max_batch):
    """Pipelined engine: the ``lm.decode.pipelined`` family (GPipe
    fill-drain over a ('stage',) mesh; needs >= 2 devices, mesh-free)."""
    import jax

    from repro.models.lm import ModelConfig, init
    from repro.serving import SamplerConfig, ServeEngine

    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=61, remat="none", dtype="float32")
    params = init(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, max_batch=max_batch, max_len=64,
                       sampler=SamplerConfig(temperature=0.0),
                       pipeline_stages=2)


def _build_cnn(mesh, max_batch):
    import jax
    import numpy as np

    from repro.serving import VisionEngine, VisionRequest
    from repro.serving.vision import MODEL_ZOO

    module = MODEL_ZOO["alexnet"]
    params = module.init(jax.random.PRNGKey(0), image=64, num_classes=16)
    eng = VisionEngine({"alexnet": params}, backend="int-direct",
                       max_batch=max_batch, mesh=mesh)
    rng = np.random.default_rng(0)
    imgs = rng.standard_normal((max_batch, 64, 64, 3)).astype(np.float32)
    for rid in range(max_batch):
        eng.submit(VisionRequest(rid=rid, image=imgs[rid],
                                 model="alexnet", precision="<4:4>"))
    eng.run()   # records the dispatched bucket shapes hot_paths() lints
    return eng


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)
    lint = sub.add_parser("lint", help="lint registered hot paths")
    lint.add_argument("--workload", choices=("lm", "cnn", "all"),
                      default="all")
    lint.add_argument("--mesh", default="1x1", metavar="AxB",
                      help="data x model host topology (forced via "
                      "XLA_FLAGS before jax import); 1x1 = mesh-free")
    lint.add_argument("--max-batch", type=int, default=4)
    lint.add_argument("--rules", default=None,
                      help="comma-separated rule subset (default: all)")
    args = ap.parse_args(argv)

    data, model = _parse_mesh(args.mesh)
    n_dev = data * model
    if n_dev > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={n_dev}"
                .strip())
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import jax

    from repro import analysis

    if len(jax.devices()) < n_dev:
        raise SystemExit(f"--mesh {args.mesh} needs {n_dev} devices, have "
                         f"{len(jax.devices())} (jax imported before the "
                         f"XLA_FLAGS force?)")
    mesh = None
    if n_dev > 1:
        from repro.launch.mesh import make_serve_mesh

        mesh = make_serve_mesh(model)

    engines = []
    if args.workload in ("lm", "all"):
        engines.append(_build_lm(mesh, args.max_batch))
        engines.append(_build_moe_lm(mesh, args.max_batch))
        if n_dev > 1:   # pipeline stages need a second device
            engines.append(_build_pipe_lm(args.max_batch))
    if args.workload in ("cnn", "all"):
        engines.append(_build_cnn(mesh, args.max_batch))

    rules = args.rules.split(",") if args.rules else None
    violations = analysis.lint_registered(rules=rules)
    # The gateway has no jitted programs; its hot-path contract is the
    # thread-ownership rule, linted on the module AST every run.
    violations += analysis.threads.check_gateway()

    n_progs = sum(len(hp.programs) for hp in analysis.iter_hot_paths())
    print(f"linted {n_progs} program(s) across "
          f"{len(analysis.registered())} engine(s) on {n_dev} device(s) "
          f"+ gateway thread-ownership")
    print(analysis.format_report(violations))
    for eng in engines:
        eng.close()
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
