"""The hot-path rule registry.

Each rule is a function ``rule(hot_path) -> iterable[Violation]`` under a
stable registry name; :func:`run_rules` drives every registered rule over
one :class:`~repro.analysis.hotpath.HotPath` and collects violations with
``"<hotpath>:<program>"`` attribution. The six core rules encode the
invariants the serving performance story rests on (DESIGN.md §10):

collective-budget   textual all-gather/all-reduce/all-to-all/permute
                    counts within the declared budget, all-gather results
                    under the byte bound, counts flat across the pow2
                    drain/scan family (generalizes the PR 3/4 in-test HLO
                    assertions).
donation-honored    every declared donate argnum's leaves actually alias
                    in the compiled executable — no silent copy fallback.
dtype-discipline    no f64 anywhere, no f32 dot/conv inside declared-bf16
                    programs, packed uint32 planes never converted to
                    float.
no-host-sync        no callback/infeed/outfeed/host-transfer primitive in
                    a hot program (they serialize the dispatch queue).
recompile-hazard    no non-weakly-typed host scalars in example call args
                    (a np.float32 temperature fragments the pow2 bucket
                    compile bound that python-float args share).
tile-legality       autotuner TuneDecisions carried by packed weights are
                    legal as requested: pallas only where GSPMD permits
                    it, tile requests dividing the deployment shapes so
                    ``kernels.ops.matmul_tiles`` never silently rewrites
                    a decision the cache claims was measured.
"""
from __future__ import annotations

import numpy as np

from repro.analysis import hlo
from repro.analysis.hotpath import Violation

RULES: dict = {}


def rule(name: str):
    def deco(fn):
        RULES[name] = fn
        return fn
    return deco


def run_rules(hp, names=None):
    if names is not None:
        unknown = set(names) - set(RULES)
        if unknown:
            raise KeyError(f"unknown rules {sorted(unknown)}; "
                           f"registered: {sorted(RULES)}")
    out = []
    for name, fn in RULES.items():
        if names is not None and name not in names:
            continue
        out += list(fn(hp))
    return out


def _tag(hp, prog) -> str:
    return f"{hp.name}:{prog.label}"


# -- collective-budget -------------------------------------------------------

@rule("collective-budget")
def collective_budget(hp):
    caps = dict(hp.budget.collectives)
    bound = hp.budget.max_gather_bytes
    counts = {}
    for prog in hp.programs:
        txt = prog.compiled_text()
        c = hlo.collective_counts(txt)
        counts[prog.label] = c
        for kind, cap in caps.items():
            if cap is not None and c.get(kind, 0) > cap:
                yield Violation(_tag(hp, prog), "collective-budget",
                                f"{c[kind]} x {kind} exceeds budget {cap}")
        if bound is not None:
            big = [s for s in hlo.gather_sizes(txt) if s > bound]
            if big:
                yield Violation(
                    _tag(hp, prog), "collective-budget",
                    f"all-gather result(s) over {bound} bytes: "
                    f"{sorted(big)[-3:]} — weight/KV-sized resharding in "
                    f"a steady-state program")
    if hp.budget.scan_flat and len(counts) > 1:
        first_label = hp.programs[0].label
        first = counts[first_label]
        for label, c in counts.items():
            if c != first:
                yield Violation(
                    f"{hp.name}:*", "collective-budget",
                    f"collective counts not flat across the family: "
                    f"{first_label}={first} vs {label}={c} — a collective "
                    f"moved inside the scan body")
                break


# -- donation-honored --------------------------------------------------------

@rule("donation-honored")
def donation_honored(hp):
    if not hp.budget.donate:
        return
    import jax

    for prog in hp.programs:
        txt = prog.compiled_text()
        aliased = hlo.input_output_aliases(txt)
        ranges, total = [], 0
        for a in prog.args:
            n = len(jax.tree_util.tree_leaves(a))
            ranges.append((total, total + n))
            total += n
        total += len(jax.tree_util.tree_leaves(prog.kwargs))
        # jit prunes unused args from the executable; map each flat arg
        # leaf to its surviving parameter number before checking aliases.
        kept = sorted(prog.kept_var_idx(total))
        param_of = {leaf: i for i, leaf in enumerate(kept)}
        n_params = hlo.entry_param_count(txt)
        if n_params is not None and n_params != len(kept):
            yield Violation(
                _tag(hp, prog), "donation-honored",
                f"cannot map donate argnums: executable has {n_params} "
                f"params for {len(kept)} kept arg leaves")
            continue
        for argnum in hp.budget.donate:
            lo, hi = ranges[argnum]
            pruned = [i for i in range(lo, hi) if i not in param_of]
            missing = [param_of[i] for i in range(lo, hi)
                       if i in param_of and param_of[i] not in aliased]
            if pruned:
                yield Violation(
                    _tag(hp, prog), "donation-honored",
                    f"donated argnum {argnum}: {len(pruned)} buffer(s) "
                    f"unused by the program (pruned from the executable) "
                    f"— dead donation")
            if missing:
                yield Violation(
                    _tag(hp, prog), "donation-honored",
                    f"donated argnum {argnum}: {len(missing)}/{hi - lo} "
                    f"buffer(s) not aliased in the executable (params "
                    f"{missing[:4]}{'...' if len(missing) > 4 else ''}) — "
                    f"silent copy fallback")


# -- dtype-discipline --------------------------------------------------------

@rule("dtype-discipline")
def dtype_discipline(hp):
    for prog in hp.programs:
        txt = prog.compiled_text()
        if not hp.budget.allow_f64 and hlo.has_f64(txt):
            yield Violation(_tag(hp, prog), "dtype-discipline",
                            "f64 buffer in compiled program")
        if prog.fn is None:   # injected-text program: no jaxpr to walk
            continue
        if hp.budget.compute_dtype == "bf16":
            ups = hlo.f32_matmul_eqns(prog.jaxpr())
            if ups:
                yield Violation(
                    _tag(hp, prog), "dtype-discipline",
                    f"{len(ups)} f32 {'/'.join(sorted(set(ups)))} op(s) "
                    f"inside a declared-bf16 program")
        for site in hlo.plane_float_converts(prog.jaxpr()):
            yield Violation(
                _tag(hp, prog), "dtype-discipline",
                f"packed uint32 plane touched by float op: {site}")


# -- no-host-sync ------------------------------------------------------------

@rule("no-host-sync")
def no_host_sync(hp):
    if hp.budget.allow_host_sync:
        return
    for prog in hp.programs:
        prims = [] if prog.fn is None \
            else hlo.callback_primitives(prog.jaxpr())
        for p in prims:
            yield Violation(_tag(hp, prog), "no-host-sync",
                            f"host-sync primitive in trace: {p}")
        if not prims:   # compiled-side net for callbacks jaxprs can hide
            for site in hlo.host_callback_sites(prog.compiled_text()):
                yield Violation(_tag(hp, prog), "no-host-sync",
                                f"host round-trip in executable: {site}")


# -- recompile-hazard --------------------------------------------------------

@rule("recompile-hazard")
def recompile_hazard(hp):
    if not hp.budget.check_weak_scalars:
        return
    for prog in hp.programs:
        for i, a in enumerate(prog.args):
            if isinstance(a, (bool, int, float)) or a is None:
                continue   # python scalars are weakly typed: shared program
            if isinstance(a, np.generic) or \
                    (isinstance(a, np.ndarray) and a.ndim == 0):
                yield Violation(
                    _tag(hp, prog), "recompile-hazard",
                    f"arg {i} is a committed numpy scalar "
                    f"({np.dtype(a.dtype).name}); a python scalar would "
                    f"stay weakly typed and share the compiled program")
                continue
            aval = getattr(a, "aval", None)
            if aval is not None and getattr(aval, "ndim", 1) == 0 \
                    and not getattr(aval, "weak_type", True):
                yield Violation(
                    _tag(hp, prog), "recompile-hazard",
                    f"arg {i} is a 0-d non-weakly-typed device scalar "
                    f"({aval.str_short()}); each distinct dtype forks the "
                    f"compile cache")


# -- tile-legality -----------------------------------------------------------

def _packed_leaves(args):
    import jax

    from repro.core.packed import PackedConvWeight, PackedWeight

    def is_packed(x):
        return isinstance(x, (PackedWeight, PackedConvWeight))

    for a in args:
        for leaf in jax.tree_util.tree_leaves(a, is_leaf=is_packed):
            if is_packed(leaf):
                yield leaf


@rule("tile-legality")
def tile_legality(hp):
    from repro.core.packed import PackedConvWeight

    for prog in hp.programs:
        for pw in _packed_leaves(prog.args):
            tune = getattr(pw, "tune", None)
            if tune is None:
                continue
            if tune.backend == "pallas" and not hp.budget.pallas_ok:
                yield Violation(
                    _tag(hp, prog), "tile-legality",
                    "TuneDecision selects 'pallas' under a sharding mesh "
                    "(no GSPMD rule: the planes would all-gather every "
                    "step)")
            mat = pw.mat if isinstance(pw, PackedConvWeight) else pw
            # shape[-1], not [1]: expert-stacked banks carry (E, K, N) (or
            # (R, E, K, N)) codes — N is always the trailing dim.
            n = int(mat.codes.shape[-1])
            kw = int(mat.planes.shape[-1])
            m = None if isinstance(pw, PackedConvWeight) \
                else hp.budget.m_hint
            for dim_name, dim, req in (("m", m, tune.bm),
                                       ("n", n, tune.bn),
                                       ("kw", kw, tune.bkw)):
                if req is None or dim is None:
                    continue
                if dim % req:
                    yield Violation(
                        _tag(hp, prog), "tile-legality",
                        f"tile request b{dim_name}={req} does not divide "
                        f"{dim_name}={dim}; matmul_tiles would silently "
                        f"legalize it — the cached decision no longer "
                        f"describes the executed kernel")
