"""Device -> architecture evaluation substrate (paper §5).

  device.py      NAND-SPIN + peripheral circuit constants (§5.1)
  hierarchy.py   subarray/mat/bank organization (§5.2)
  mapper.py      layer -> micro-operation counts (the §4 mapping scheme)
  cost_model.py  op pricing in seconds/joules
  calibrate.py   per-phase schedule-efficiency fit at the published endpoint
  simulator.py   end-to-end CNN inference latency/energy/FPS
  baselines.py   DRISA / PRIME / STT-CiM / MRIMA / IMCE analytical models
  area.py        die area + add-on breakdown (Table 3, Fig. 17)
  faults.py      STT-MRAM fault model + ECC-style mitigation (DESIGN.md §7)
"""
from .area import add_on_area_mm2, chip_area_mm2, ecc_area_mm2
from .calibrate import PAPER_CLAIMS, Calibration, calibrated
from .cost_model import Cost, CostModel, redundancy_factors
from .device import NandSpinDevice, PeripheralCircuits
from .faults import (FaultConfig, disturb_packed, inject_packed, inject_tree,
                     read_disturb_scope, repair_packed, repair_tree,
                     verify_columns)
from .hierarchy import Geometry
from .simulator import SimResult, peak_gops, simulate, simulate_model

__all__ = [
    "add_on_area_mm2", "chip_area_mm2", "ecc_area_mm2", "PAPER_CLAIMS",
    "Calibration", "calibrated", "Cost", "CostModel", "redundancy_factors",
    "NandSpinDevice", "PeripheralCircuits", "FaultConfig", "disturb_packed",
    "inject_packed", "inject_tree", "read_disturb_scope", "repair_packed",
    "repair_tree", "verify_columns", "Geometry", "SimResult", "peak_gops",
    "simulate",
    "simulate_model",
]
