"""Calibration of the architecture simulator against the paper's endpoint.

The paper publishes (i) device/circuit constants (§5.1) and (ii) end-to-end
measurements: ResNet50 at 64 MB / 128-bit bus runs at 80.6 FPS (Table 3)
with the Fig. 16 latency/energy phase breakdown. The op-count model in
:mod:`repro.pim.mapper` is mechanistic but cannot capture every scheduling
detail of the in-house simulator (tree-reduction depth in pooling, tag/
result row maintenance in comparisons, the exact replication the mapper
grants each conv layer). Following standard simulator-calibration practice,
we fit one latency and one energy *schedule-efficiency factor per phase* at
the published endpoint and hold them fixed everywhere else.

Everything the benchmarks *sweep* — capacity, bus width, ⟨W:I⟩ precision,
model choice — therefore varies only through the mechanistic op counts;
the calibration is a single fixed point, not a per-experiment fudge.

Factor semantics:
  lat[phase] > 1  -> the real schedule is slower than the op-count lower
                     bound (serialization the mapper does not see)
  lat["conv"] < 1 -> the real schedule is *faster*: the paper replicates
                     input bit-planes across mats so more subarrays can
                     work on one layer than pure residency would allow
"""
from __future__ import annotations

import dataclasses
import functools

# Paper Fig. 16 (ResNet50) phase fractions and Table 3 throughput.
PAPER_FPS_RESNET50 = 80.6
PAPER_LATENCY_FRACTIONS = {
    "load": 0.384, "conv": 0.339, "transfer": 0.048,
    "pool": 0.132, "bn": 0.044, "quant": 0.053,
}
PAPER_ENERGY_FRACTIONS = {
    "load": 0.326, "conv": 0.355, "transfer": 0.049,
    "pool": 0.154, "bn": 0.051, "quant": 0.065,
}
# Headline comparison claims used by the validation tests / benchmarks.
PAPER_CLAIMS = {
    "speedup_vs_dram": 6.3, "speedup_vs_stt": 2.6,
    "speedup_vs_reram": 13.5, "speedup_vs_sot": 5.1,
    "energy_vs_dram": 2.3, "energy_vs_stt": 1.4,
    "energy_vs_reram": 12.3, "energy_vs_sot": 2.6,
    "throughput_fps": 80.6, "area_mm2": 64.5,
}


@dataclasses.dataclass(frozen=True)
class Calibration:
    lat: dict
    energy: dict

    @staticmethod
    def identity() -> "Calibration":
        ones = {p: 1.0 for p in PAPER_LATENCY_FRACTIONS}
        return Calibration(lat=dict(ones), energy=dict(ones))


def crosscheck_measured(rows: list) -> list:
    """Cross-check measured vision-engine throughput against the simulator.

    ``rows`` are measured serving cells (``benchmarks/cnn_bench.py``
    throughput rows: model / image / precision ``<W:I>`` / img_s). For each
    quantized cell of a simulator-known model, price the same (model,
    image, ⟨W:I⟩) on the calibrated NAND-SPIN architecture and report the
    measured-to-simulated fps ratio.

    The two numbers answer different questions — the engine measures the
    TPU/CPU *reproduction* of the dataflow, the simulator prices the
    paper's *hardware* — so the ratio is a tracked trajectory, not an
    agreement check: a sudden shift flags either a serving-path perf
    regression or a simulator/calibration change, which is exactly what a
    fixed-point calibration must notice.
    """
    import re

    from .simulator import simulate_model

    out = []
    for r in rows:
        m = re.match(r"^<(\d+):(\d+)>$", str(r.get("precision", "")))
        if not m:
            continue                      # float reference cells: nothing to price
        wb, ab = int(m.group(1)), int(m.group(2))
        try:
            sim = simulate_model(r["model"], image=int(r.get("image", 224)),
                                 ab=ab, wb=wb)
            fps = round(sim.fps, 2)
        except KeyError:
            # Model outside the simulator registry (models/cnn/specs.py):
            # keep the row with a null prediction so the gap is visible in
            # the artifact instead of silently dropping the cell.
            fps = None
        measured = float(r.get("img_s", 0.0))
        out.append({
            "model": r["model"], "image": r.get("image", 224),
            "W:I": f"<{wb}:{ab}>", "batch": r.get("batch", 1),
            # The Eq. 1 backend the measured cell actually ran (the fixed
            # engine constant, or the autotuner's pick when the serving
            # path was tuned) — a measured/sim shift is only attributable
            # if the artifact records which execution strategy moved.
            "backend": r.get("backend", "unknown"),
            "measured_img_s": round(measured, 2),
            "sim_fps": fps,
            "measured/sim": round(measured / fps, 4) if fps else None,
        })
    return out


@functools.lru_cache(maxsize=1)
def calibrated() -> Calibration:
    """Fit the per-phase factors at the ResNet50 ⟨8:8⟩ / 64 MB endpoint."""
    from .simulator import simulate_model

    raw = simulate_model("resnet50", util=Calibration.identity())
    total = 1.0 / PAPER_FPS_RESNET50
    lat = {
        p: PAPER_LATENCY_FRACTIONS[p] * total / max(c.latency, 1e-15)
        for p, c in raw.phases.items()
    }
    # Energy: anchor the conv phase at its mechanistic value (its op pricing
    # is the best-grounded: sense energies straight from §5.1) and set the
    # other phases to the published fractions around it.
    conv_e = raw.phases["conv"].energy
    dyn_total = conv_e / PAPER_ENERGY_FRACTIONS["conv"]
    energy = {
        p: PAPER_ENERGY_FRACTIONS[p] * dyn_total / max(c.energy, 1e-15)
        for p, c in raw.phases.items()
    }
    return Calibration(lat=lat, energy=energy)
