"""Cost-model-driven backend/tiling autotuner (DESIGN.md §9).

BENCH_kernels.json shows the best Eq. 1 backend *flips* with shape and
precision: the popcount dataflow scales with the W*I plane-pair count, the
direct integer matmul is precision-flat, and the MXU plane path sits in
between — so a fixed backend constant leaves 2-10x on the table somewhere
in every deployment. This module closes the loop the paper's architecture
already has: the chip/bank/subarray mapper (:func:`repro.pim.mapper.
map_gemm`) and its price list (:class:`repro.pim.cost_model.CostModel`)
rank the *real* kernel candidates, and the verdict ships to prepack time
as a :class:`~repro.core.packed.TuneDecision` on each packed weight.

Pipeline per (m, k, n, <W:I>) GEMM:

  1. enumerate candidates — one per XLA backend, plus a legalized Pallas
     tile lattice (bm, bn, bkw) when "pallas" is allowed;
  2. rank analytically: ``map_gemm`` expands the candidate's schedule into
     subarray micro-ops (plane pairs for the bit-serial backends, a single
     full-width pass for int-direct) and ``CostModel`` prices them; a
     per-backend throughput factor (``_RATES``, fitted once against the
     committed BENCH_kernels.json trends per device kind) converts the
     NAND-SPIN price into a relative execution-time estimate;
  3. near-ties (within ``_TIE_BAND``) are broken by
     :func:`repro.roofline.hlo_cost.analyze` on the *compiled* XLA
     candidate — a roofline max(flops/peak, bytes/bw) of the lowered HLO;
  4. ``mode="measure"`` refines the top candidate per backend by actual
     wall-clock measurement (injectable ``measure`` fn; the default
     synthesizes operands once);
  5. the decision persists in a :class:`TuningCache` — a JSON file keyed
     by (shape, precision, backend-set, device-kind) and stamped with a
     code version hashed from the modules that define the kernels'
     semantics, so editing the kernels stales the cache instead of
     silently serving outdated picks.

Tuning may change speed, never bits: every backend computes the identical
integer P (mod 2^32), asserted across the candidate set in
tests/test_autotune.py.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import math
import os
import time
import warnings

from repro.core.packed import (PackedConvWeight, PackedWeight, TuneDecision,
                               prepack)
from repro.models.cnn.specs import GemmSpec

from .cost_model import CostModel
from .hierarchy import Geometry
from .mapper import map_gemm

# Backends with an XLA lowering — always safe candidates. "pallas" joins
# the set only when requested explicitly or on a real TPU backend: in
# interpret mode (CPU) the kernel runs the Python loop body, which is a
# semantics oracle, not a contender.
XLA_BACKENDS = ("popcount", "mxu-plane", "int-direct")
ALL_BACKENDS = XLA_BACKENDS + ("pallas",)

# Pallas tile request lattice; every point is legalized against the actual
# (m, n, kw) by kernels.ops.matmul_tiles before it becomes a candidate, so
# the set collapses for small operands.
_TILE_BM = (8, 32, 128, 256)
_TILE_BN = (128, 256, 512)
_TILE_BKW = (32, 128, 512)

# Relative schedule drain rates per backend and device kind: each
# candidate's time estimate is its mapper price divided by this factor
# (popcount = 1.0 defines the unit). int-direct's single full-width pass
# is priced by map_gemm(ab=wb=1), whose cost relative to the full
# plane-pair sweep *shrinks* as W*I grows (the sweep's extra row-ops are
# only partly absorbed by the residency parallel width) — so one flat
# rate reproduces the measured precision crossover: 0.2 puts it where
# BENCH_kernels.json flips from popcount (low-precision, few pairs) to
# int-direct (<8:8>, 64 pairs), right for 14/15 of the committed
# backend_comparison grid. mxu-plane pays bf16 plane materialization it
# never earns back off-TPU; on TPU the systolic array flips both
# relations. Calibration constants of the *ranking*, not the simulator:
# measure mode bypasses them entirely.
_RATES = {
    "default": {"popcount": 1.0, "mxu-plane": 0.4, "int-direct": 0.2,
                "pallas": 0.9},
    "tpu": {"popcount": 1.0, "mxu-plane": 4.0, "int-direct": 0.5,
            "pallas": 2.5},
}

_TIE_BAND = 1.10          # analytic near-tie band feeding the HLO tie-break
_VMEM_BUDGET = 8 << 20    # matches core.mapping.plan_matmul's default
_GEO = Geometry()


# ---------------------------------------------------------------------------
# Environment fingerprints
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def device_kind() -> str:
    import jax

    try:
        return jax.devices()[0].device_kind.replace(" ", "-").lower()
    except Exception:  # pragma: no cover - backend init failure
        return "unknown"


@functools.lru_cache(maxsize=1)
def code_version() -> str:
    """Hash of the modules defining kernel semantics + this ranker.

    A cache entry is only as good as the code that produced and consumes
    it: editing the kernels, the tile planner or the autotuner itself must
    stale every persisted decision (fall back to fresh cost-model picks),
    never silently serve them.
    """
    import importlib

    mods = [importlib.import_module(m) for m in
            ("repro.core.bitserial", "repro.core.mapping",
             "repro.kernels.ops", "repro.kernels.bitserial_matmul",
             "repro.kernels.conv2d_fused")]
    h = hashlib.md5()
    for mod in mods:
        try:
            with open(mod.__file__, "rb") as fh:
                h.update(fh.read())
        except OSError:  # pragma: no cover - frozen/zipped install
            h.update(mod.__name__.encode())
    with open(__file__, "rb") as fh:
        h.update(fh.read())
    return h.hexdigest()[:12]


def _rates() -> dict:
    import jax

    key = "tpu" if jax.default_backend() == "tpu" else "default"
    return _RATES[key]


def default_backends(mesh=None) -> tuple:
    """Candidate set for engine prepack: the XLA backends everywhere, plus
    pallas on a real TPU without a mesh (pallas_call has no GSPMD rule —
    the same restriction ServeEngine/VisionEngine enforce on their
    configured backend)."""
    import jax

    out = XLA_BACKENDS
    if mesh is None and jax.default_backend() == "tpu":
        out = out + ("pallas",)
    return out


# ---------------------------------------------------------------------------
# Candidate enumeration + analytic ranking
# ---------------------------------------------------------------------------

def gemm_candidates(m: int, k: int, n: int, a_bits: int, w_bits: int,
                    backends=XLA_BACKENDS) -> list:
    """One TuneDecision per XLA backend + the legalized Pallas tile set."""
    from repro.kernels import ops as _kops

    out = []
    for be in backends:
        if be != "pallas":
            out.append(TuneDecision(backend=be))
            continue
        kw = max(1, -(-k // 32))
        seen = set()
        for bm in _TILE_BM:
            for bn in _TILE_BN:
                for bkw in _TILE_BKW:
                    t = _kops.matmul_tiles(m, n, kw, a_bits, w_bits,
                                           bm, bn, bkw)
                    if t in seen:
                        continue
                    seen.add(t)
                    out.append(TuneDecision(backend="pallas", bm=t[0],
                                            bn=t[1], bkw=t[2]))
    return out


def _gemm_spec(m: int, k: int, n: int) -> GemmSpec:
    return GemmSpec(name="autotune", kind="fc", m=m, k=k, n=n,
                    out_elems=m * n, in_elems=m * k, weight_elems=k * n)


def _price(spec: GemmSpec, ab: int, wb: int) -> float:
    """NAND-SPIN schedule latency for one (ab x wb)-plane GEMM pass."""
    cm = CostModel(_GEO)
    oc = map_gemm(spec, _GEO, ab, wb)
    c = cm.price_rowops(oc)
    c += cm.price_programs(oc)
    c += cm.price_bus(oc)
    c += cm.price_local(oc)
    return c.latency


def _tile_factor(m: int, k: int, n: int, a_bits: int, w_bits: int,
                 d: TuneDecision) -> float:
    """Pallas tile quality multiplier: grid-step overhead, the bn%128
    unchunked-fallback path, and VMEM overflow. Purely relative — it orders
    tile candidates of one shape, nothing else."""
    kw = max(1, -(-k // 32))
    bm, bn, bkw = d.bm or m, d.bn or n, d.bkw or kw
    steps = (math.ceil(m / bm) * math.ceil(n / bn) * math.ceil(kw / bkw))
    ws = (a_bits * bm * bkw + w_bits * bn * bkw + bm * bn) * 4
    f = 1.0 + 0.002 * (steps - 1)
    if bn % 128:
        f *= 1.5          # loses the _OC lane-chunk path in the kernel
    if ws > _VMEM_BUDGET:
        f *= 4.0          # working set spills the per-step VMEM budget
    return f


def analytic_gemm_cost(m: int, k: int, n: int, a_bits: int, w_bits: int,
                       d: TuneDecision) -> float:
    """Relative execution-time estimate of one candidate (see module doc).

    The bit-serial backends run the full ab x wb plane-pair schedule; the
    direct integer matmul is one full-width pass (ab = wb = 1 in the
    mapper's schedule) whose row-ops retire at the backend's own rate.
    """
    spec = _gemm_spec(m, k, n)
    if d.backend == "int-direct":
        base = _price(spec, 1, 1)
    else:
        base = _price(spec, a_bits, w_bits)
    t = base / _rates()[d.backend]
    if d.backend == "pallas":
        t *= _tile_factor(m, k, n, a_bits, w_bits, d)
    return t


# ---------------------------------------------------------------------------
# HLO roofline tie-break + measurement refinement
# ---------------------------------------------------------------------------

def roofline_time(m: int, k: int, n: int, a_bits: int, w_bits: int,
                  backend: str) -> float | None:
    """Roofline time of the compiled XLA candidate (tie-break only).

    Lowers the exact prepacked dispatch the serving path runs, walks the
    optimized HLO with :func:`repro.roofline.hlo_cost.analyze`, and prices
    it at the roofline max(flops/peak, bytes/bw). None when the candidate
    has no analyzable HLO (pallas interpret mode lowers to a callback) or
    lowering fails — callers fall back to the analytic order.
    """
    if backend == "pallas":
        return None
    try:
        import jax
        import jax.numpy as jnp

        from repro.core import bitserial
        from repro.core.quantize import QuantParams
        from repro.roofline import hlo_cost, hw

        kw = max(1, -(-k // 32))
        w = PackedWeight(
            codes=jax.ShapeDtypeStruct((k, n), jnp.int32),
            planes=jax.ShapeDtypeStruct((w_bits, n, kw), jnp.uint32),
            col_sums=jax.ShapeDtypeStruct((n,), jnp.int32),
            wq=QuantParams(scale=jax.ShapeDtypeStruct((), jnp.float32),
                           qmin=jax.ShapeDtypeStruct((), jnp.float32),
                           bits=w_bits))
        qa = jax.ShapeDtypeStruct((m, k), jnp.int32)
        fn = jax.jit(functools.partial(bitserial.int_matmul_prepacked,
                                       a_bits=a_bits, backend=backend))
        txt = fn.lower(qa, w).compile().as_text()
        c = hlo_cost.analyze(txt)
        return max(c.flops / hw.PEAK_FLOPS_BF16, c.bytes / hw.HBM_BW)
    except Exception:
        return None


def measure_gemm(d: TuneDecision, m: int, k: int, n: int, a_bits: int,
                 w_bits: int, iters: int = 2) -> float | None:
    """Default measurement hook: wall-clock one candidate on synthetic
    operands through the real prepacked dispatch. Returns seconds, or None
    when the candidate fails to run (it is then dropped, not picked)."""
    try:
        import jax
        import jax.numpy as jnp

        from repro.core.bitserial import int_matmul_prepacked

        key = jax.random.PRNGKey(0)
        qa = jax.random.randint(key, (m, k), 0, 2 ** a_bits, jnp.int32)
        pk = attach(prepack(jax.random.normal(
            jax.random.fold_in(key, 1), (k, n)), w_bits), d)
        fn = jax.jit(functools.partial(int_matmul_prepacked, a_bits=a_bits))
        jax.block_until_ready(fn(qa, pk))       # compile + warm
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(qa, pk))
        return (time.perf_counter() - t0) / iters
    except Exception:
        return None


# ---------------------------------------------------------------------------
# Decisions
# ---------------------------------------------------------------------------

def gemm_key(m: int, k: int, n: int, a_bits: int, w_bits: int,
             backends) -> str:
    return (f"gemm:{m}x{k}x{n}:<{w_bits}:{a_bits}>:"
            f"be={'+'.join(sorted(backends))}:dev={device_kind()}")


def conv_key(n: int, h: int, w: int, c: int, o: int, kh: int, kw: int,
             stride: int, padding: int, a_bits: int, w_bits: int,
             backends) -> str:
    return (f"conv:{n}x{h}x{w}x{c}:o{o}:k{kh}x{kw}:s{stride}p{padding}:"
            f"<{w_bits}:{a_bits}>:be={'+'.join(sorted(backends))}:"
            f"dev={device_kind()}")


def decide_gemm(m: int, k: int, n: int, a_bits: int, w_bits: int, *,
                backends=None, mode: str = "cost", cache=None,
                measure=None, hlo_tiebreak: bool = True) -> TuneDecision:
    """Pick (backend, tiles) for an (m, k, n) <W:I> GEMM.

    Deterministic for a fixed cache and candidate set: the analytic
    ranking is pure arithmetic, ties within the band resolve by the HLO
    roofline (itself deterministic) and finally by enumeration order.
    ``mode="measure"`` additionally times the best candidate per backend
    (``measure(decision, m, k, n, a_bits, w_bits) -> seconds | None``;
    default :func:`measure_gemm`) and picks the fastest.
    """
    if mode not in ("cost", "measure"):
        raise ValueError(f"autotune mode {mode!r}: want 'cost' | 'measure'")
    backends = tuple(backends) if backends else XLA_BACKENDS
    key = gemm_key(m, k, n, a_bits, w_bits, backends)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            return hit
    cands = gemm_candidates(m, k, n, a_bits, w_bits, backends)
    scored = sorted(
        (analytic_gemm_cost(m, k, n, a_bits, w_bits, d), i, d)
        for i, d in enumerate(cands))
    best_cost, _, best = scored[0]

    if hlo_tiebreak:
        ties = [d for c, _, d in scored
                if c <= best_cost * _TIE_BAND and d.backend != "pallas"]
        if len({d.backend for d in ties}) > 1:
            rt = [(roofline_time(m, k, n, a_bits, w_bits, d.backend), i, d)
                  for i, d in enumerate(ties)]
            rt = [x for x in rt if x[0] is not None]
            if rt:
                best = min(rt)[2]

    if mode == "measure":
        measure = measure or measure_gemm
        # Top analytic candidate per backend; measurement settles between
        # backends, the analytic order settles tiles within one.
        heads = {}
        for c, i, d in scored:
            heads.setdefault(d.backend, d)
        timed = [(t, i, d) for i, d in enumerate(heads.values())
                 if (t := measure(d, m, k, n, a_bits, w_bits)) is not None]
        if timed:
            best = min(timed)[2]

    if cache is not None:
        cache.put(key, best, mode=mode)
    return best


def decide_conv(n: int, h: int, w: int, c: int, o: int, kh: int, kw: int,
                *, stride: int = 1, padding: int = 0, a_bits: int = 8,
                w_bits: int = 8, backends=None, mode: str = "cost",
                cache=None, measure=None) -> tuple:
    """Pick (conv_mode, bo, backend) for a conv layer; returns the pair
    (conv decision, im2col-matmul decision) that :func:`attach_conv`
    installs on a :class:`PackedConvWeight`.

    Candidates: the materialized im2col path per allowed backend (priced
    as the underlying GEMM plus the patch-matrix bus traffic the paper's
    fused schedule never pays — zero for 1x1 kernels, where im2col is a
    reshape), and the fused implicit-im2col kernel per O-block when
    "pallas" is allowed.
    """
    if mode not in ("cost", "measure"):
        raise ValueError(f"autotune mode {mode!r}: want 'cost' | 'measure'")
    backends = tuple(backends) if backends else XLA_BACKENDS
    ckey = conv_key(n, h, w, c, o, kh, kw, stride, padding, a_bits, w_bits,
                    backends)
    if cache is not None:
        hit = cache.get(ckey)
        if hit is not None and isinstance(hit, tuple):
            return hit
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    m, kdim = n * oh * ow, kh * kw * c
    spec = _gemm_spec(m, kdim, o)
    cm = CostModel(_GEO)
    # Patch-matrix blow-up the materialized path streams (int32 codes),
    # priced on the same global bus as the mapper's weight broadcasts.
    patch_bits = 0 if kh == kw == 1 else m * kdim * 32
    patch_t = cm.bus_time(patch_bits)

    scored = []
    for i, be in enumerate(backends):
        if be == "pallas":
            continue
        d = TuneDecision(backend=be, conv_mode="im2col")
        t = analytic_gemm_cost(m, kdim, o, a_bits, w_bits, d) + patch_t
        scored.append((t, i, d))
    if "pallas" in backends:
        d = TuneDecision(backend="pallas", conv_mode="im2col")
        scored.append((analytic_gemm_cost(m, kdim, o, a_bits, w_bits, d)
                       + patch_t, len(backends), d))
        base = _price(spec, a_bits, w_bits) / _rates()["pallas"]
        for j, bo in enumerate((64, 128, 256)):
            steps = math.ceil(o / min(bo, o))
            t = base * (1.0 + 0.002 * (steps - 1))
            if bo % 128 and bo < min(o, 128):
                t *= 1.2
            scored.append((t, len(backends) + 1 + j,
                           TuneDecision(backend="pallas", conv_mode="fused",
                                        bo=bo)))
    scored.sort()
    best = scored[0][2]
    if mode == "measure" and measure is not None:
        heads, seen = [], set()
        for t, i, d in scored:
            hk = (d.backend, d.conv_mode)
            if hk not in seen:
                seen.add(hk)
                heads.append(d)
        timed = [(t, i, d) for i, d in enumerate(heads)
                 if (t := measure(d)) is not None]
        if timed:
            best = min(timed)[2]
    mat = TuneDecision(backend=best.backend if best.conv_mode == "im2col"
                       else "popcount")
    out = (best, mat)
    if cache is not None:
        cache.put(ckey, out, mode=mode)
    return out


# ---------------------------------------------------------------------------
# Attachment: decisions -> packed-weight trees
# ---------------------------------------------------------------------------

def attach(pw: PackedWeight, d: TuneDecision | None) -> PackedWeight:
    """Install a decision on a packed weight (static metadata only — the
    leaf buffers, shardings and checkpoint layout are untouched)."""
    return dataclasses.replace(pw, tune=d)


def attach_conv(pcw: PackedConvWeight, d: TuneDecision | None,
                mat: TuneDecision | None = None) -> PackedConvWeight:
    return dataclasses.replace(pcw, tune=d,
                               mat=dataclasses.replace(pcw.mat, tune=mat))


_MOE_EXPERT_NAMES = ("w_in", "w_out", "w_gate")


def _is_expert_path(path) -> bool:
    """True for packed leaves living at ``...['ffn']...['w_in'|'w_out'|
    'w_gate']`` — the expert-stacked MoE banks (callers only enable the
    check for MoE configs, where every ffn projection is an expert bank)."""
    keys = [getattr(k, "key", None) for k in path]
    return "ffn" in keys and keys and keys[-1] in _MOE_EXPERT_NAMES


def tune_tree(tree, *, m_hint: int, a_bits: int, backends=None,
              mode: str = "cost", cache=None, conv_m_hint: int | None = None,
              measure=None, moe_m_hint: int | None = None):
    """Attach decisions to every packed leaf of a prepacked param tree.

    ``m_hint`` is the GEMM row count the deployment runs (the serving
    batch for LM decode / the vision FC head); ``conv_m_hint`` bounds the
    conv im2col row count (batch * input map, the stride-1 upper bound —
    the backend crossover is driven by the plane-pair count, which this
    estimate preserves). Decisions dedupe through the cache: scan-stacked
    layer leaves with equal (k, n, bits) decide once.

    ``moe_m_hint`` (MoE deployments): the expert banks' GEMMs run batched
    over every expert's capacity buffer, so their decisions key on the
    E*C dispatch row count instead of the token batch — and their
    candidate set drops "pallas" (the per-expert dispatch runs under
    ``vmap``, which the interpret-mode kernel does not batch).
    """
    import jax

    backends = tuple(backends) if backends else XLA_BACKENDS
    xla_only = tuple(b for b in backends if b != "pallas") or backends

    def visit(path, leaf):
        if isinstance(leaf, PackedConvWeight):
            _, _, _, o = leaf.kernel_shape
            kdim = leaf.mat.codes.shape[-2]
            m = conv_m_hint if conv_m_hint is not None else m_hint
            # Conv decisions from the weight alone: rank the im2col GEMM
            # (the spatial dims ride in conv_m_hint); the fused-vs-im2col
            # split stays with the shape heuristic (tune.conv_mode=None).
            d = decide_gemm(m, kdim, o, a_bits, leaf.bits,
                            backends=xla_only, mode="cost", cache=cache)
            return attach_conv(leaf, TuneDecision(backend=d.backend),
                               mat=d)
        if isinstance(leaf, PackedWeight):
            *_, k, n = leaf.codes.shape
            m, be = m_hint, backends
            if moe_m_hint is not None and _is_expert_path(path):
                m, be = moe_m_hint, xla_only
            d = decide_gemm(m, k, n, a_bits, leaf.bits,
                            backends=be, mode=mode, cache=cache,
                            measure=measure)
            return attach(leaf, d)
        return leaf

    return jax.tree_util.tree_map_with_path(
        visit, tree,
        is_leaf=lambda x: isinstance(x, (PackedWeight, PackedConvWeight)))


# ---------------------------------------------------------------------------
# The on-disk tuning cache
# ---------------------------------------------------------------------------

_FIELDS = tuple(f.name for f in dataclasses.fields(TuneDecision))


def _decision_to(d: TuneDecision) -> dict:
    return {f: getattr(d, f) for f in _FIELDS}


def _decision_from(blob: dict) -> TuneDecision:
    kw = {f: blob[f] for f in _FIELDS if f in blob}
    if not isinstance(kw.get("backend"), str):
        raise ValueError(f"bad cached decision {blob!r}")
    return TuneDecision(**kw)


class TuningCache:
    """Persisted autotune decisions with fail-safe loading.

    The file format carries a schema ``VERSION``, the :func:`code_version`
    of the kernels that produced the entries, and the decisions keyed by
    :func:`gemm_key`/:func:`conv_key` strings (which bake in shape,
    precision, backend-set and device kind). Any load problem — corrupt
    JSON, truncation, stale versions, unreadable entries — degrades to an
    empty in-memory cache with a single RuntimeWarning: decisions fall
    back to fresh cost-model picks, are re-memoized immediately (no retune
    storm — one computation per key per process), and the next save
    self-heals the file. ``path=None`` is a process-local memo.
    """

    VERSION = 1

    def __init__(self, path: str | None = None):
        self.path = path
        self.entries: dict = {}
        self._warned = False
        if path:
            self._load()

    # -- robust IO ----------------------------------------------------------

    def _warn(self, msg: str):
        if not self._warned:
            warnings.warn(f"tuning cache {self.path!r}: {msg}; "
                          "falling back to cost-model picks",
                          RuntimeWarning, stacklevel=3)
            self._warned = True

    def _load(self):
        if not os.path.exists(self.path):
            return
        try:
            with open(self.path) as fh:
                blob = json.load(fh)
            if blob.get("version") != self.VERSION:
                raise ValueError(f"schema version {blob.get('version')!r} "
                                 f"!= {self.VERSION}")
            if blob.get("code_version") != code_version():
                raise ValueError(
                    f"stale code_version {blob.get('code_version')!r}")
            self.entries = {k: self._entry_from(v)
                            for k, v in blob["entries"].items()}
        except Exception as e:
            self.entries = {}
            self._warn(f"unusable ({e!r})")

    @staticmethod
    def _entry_from(v: dict) -> dict:
        if "pair" in v:      # conv entries hold (conv, mat) decision pairs
            pair = tuple(_decision_from(p) for p in v["pair"])
            return {"decision": pair, "mode": v.get("mode", "cost")}
        return {"decision": _decision_from(v["decision"]),
                "mode": v.get("mode", "cost")}

    @staticmethod
    def _entry_to(e: dict) -> dict:
        d = e["decision"]
        if isinstance(d, tuple):
            return {"pair": [_decision_to(x) for x in d], "mode": e["mode"]}
        return {"decision": _decision_to(d), "mode": e["mode"]}

    def save(self):
        if not self.path:
            return
        blob = {"version": self.VERSION, "code_version": code_version(),
                "device_kind": device_kind(),
                "entries": {k: self._entry_to(e)
                            for k, e in self.entries.items()}}
        try:
            tmp = f"{self.path}.tmp"
            with open(tmp, "w") as fh:
                json.dump(blob, fh, indent=1)
            os.replace(tmp, self.path)   # atomic: no truncated cache files
        except OSError as e:
            self._warn(f"unwritable ({e!r})")

    def reset(self):
        """Drop the in-memory state and re-read the backing file.

        The single-warning fallback memo (``_warned``) sticks for the life
        of the instance: once a corrupt file degraded the cache, later
        ``get``s silently serve the empty memo even after the file on disk
        is repaired. Engine teardown (``ServeEngine.close`` /
        ``VisionEngine.close``) calls this so a second deploy sharing the
        cache object actually reloads the repaired file instead of
        re-tuning from scratch behind a stale warning flag."""
        self.entries = {}
        self._warned = False
        if self.path:
            self._load()

    # -- decisions ----------------------------------------------------------

    def get(self, key: str):
        e = self.entries.get(key)
        return e["decision"] if e else None

    def put(self, key: str, decision, mode: str = "cost"):
        self.entries[key] = {"decision": decision, "mode": mode}
        self.save()

    def __len__(self) -> int:
        return len(self.entries)

    # -- checkpoint round-trip (training.checkpoint extra dict) -------------

    def to_extra(self) -> dict:
        """JSON-clean payload for a checkpoint manifest's ``extra``."""
        return {"version": self.VERSION, "code_version": code_version(),
                "entries": {k: self._entry_to(e)
                            for k, e in self.entries.items()}}

    def merge_extra(self, extra: dict | None):
        """Merge a snapshot's decisions back (restore path). Version or
        code mismatches are dropped with the same single-warning fallback
        as a stale file — restored engines then re-tune from cost."""
        if not extra:
            return
        try:
            if extra.get("version") != self.VERSION:
                raise ValueError(f"schema version {extra.get('version')!r}")
            if extra.get("code_version") != code_version():
                raise ValueError("stale code_version")
            for k, v in extra["entries"].items():
                self.entries.setdefault(k, self._entry_from(v))
        except Exception as e:
            self._warn(f"snapshot entries unusable ({e!r})")
        else:
            self.save()


def as_cache(cache) -> TuningCache:
    """Coerce an engine's ``tuning_cache`` argument (path | TuningCache |
    None) into a TuningCache instance."""
    if isinstance(cache, TuningCache):
        return cache
    return TuningCache(cache)
