"""Area model (paper Table 3 + Fig. 17).

Cell-array area from a 45 nm NAND-SPIN cell (1T-1MTJ, MTJs over CMOS,
shared heavy-metal strip per 8-MTJ device), divided by the array
efficiency; the in-memory-computing add-on is the paper's measured 8.9%
with the Fig. 17 split.

``CELL_AREA_F2`` and the efficiency curve are chosen so the evaluated
64 MB platform reproduces Table 3's 64.5 mm^2; the efficiency curve's
capacity dependence (periphery amortizes, then long-wire/decoder growth
bites) drives the Fig. 13a per-area trends.
"""
from __future__ import annotations


from .hierarchy import Geometry

FEATURE_M = 45e-9
CELL_AREA_F2 = 15.7          # NAND-SPIN bit cell in F^2 (1T per MTJ + strip share)
ADD_ON_FRACTION = 0.089      # paper: "8.9% area overhead on the memory array"

# Fig. 17 split of the add-on area.
ADD_ON_BREAKDOWN = {
    "compute_units": 0.47,
    "buffer": 0.04,
    "controllers_mux": 0.21,
    "sense_amps_drivers": 0.28,
}


def array_efficiency(capacity_mb: int) -> float:
    """Fraction of die that is cell array. Rises as shared periphery
    amortizes, then falls slowly past 64 MB (wire/decoder growth)."""
    rise = capacity_mb / (capacity_mb + 18.0)
    sag = 1.0 / (1.0 + (capacity_mb / 512.0) ** 1.5)
    return 0.385 * rise * sag


def chip_area_mm2(g: Geometry) -> float:
    cell = CELL_AREA_F2 * FEATURE_M**2
    array_mm2 = g.capacity_bits * cell * 1e6
    die = array_mm2 / array_efficiency(g.capacity_mb)
    return die * (1.0 + ADD_ON_FRACTION)


def add_on_area_mm2(g: Geometry) -> dict:
    total = chip_area_mm2(g) * ADD_ON_FRACTION / (1.0 + ADD_ON_FRACTION)
    return {k: v * total for k, v in ADD_ON_BREAKDOWN.items()}


def ecc_area_mm2(g: Geometry, faults, w_bits: int = 8) -> float:
    """Extra die area of the fault-mitigation hierarchy (DESIGN.md §7).

    Redundant MSB-plane subarrays and spare columns scale the cell array by
    the storage redundancy factor; the majority voter + checksum comparator
    ride the add-on periphery, charged at the sense-amp/driver rate on the
    extra planes (each redundant copy brings its own sense path to vote).
    Zero when ``faults`` is None or carries no mitigation.
    """
    from .cost_model import redundancy_factors

    f = redundancy_factors(faults, w_bits, g.cols)["storage"]
    if f <= 1.0:
        return 0.0
    cell = CELL_AREA_F2 * FEATURE_M**2
    array_mm2 = g.capacity_bits * cell * 1e6
    extra_array = array_mm2 * (f - 1.0)
    extra_periph = (extra_array / array_efficiency(g.capacity_mb)
                    * ADD_ON_FRACTION * ADD_ON_BREAKDOWN["sense_amps_drivers"])
    return extra_array + extra_periph
