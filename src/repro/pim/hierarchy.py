"""Memory organization (paper Fig. 2 and §5.2).

bank(group) -> mat -> subarray; 4x4 subarrays of 256 rows x 128 cols per
mat, 4x4 mats per group; the evaluated platform is 64 MB with a 128-bit bus.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Geometry:
    rows: int = 256
    cols: int = 128
    subarrays_per_mat: int = 16      # 4x4
    mats_per_group: int = 16         # 4x4
    capacity_mb: int = 64
    bus_bits: int = 128

    @property
    def subarray_bits(self) -> int:
        return self.rows * self.cols

    @property
    def mat_bits(self) -> int:
        return self.subarray_bits * self.subarrays_per_mat

    @property
    def group_bits(self) -> int:
        return self.mat_bits * self.mats_per_group

    @property
    def capacity_bits(self) -> int:
        return self.capacity_mb * (1 << 20) * 8

    @property
    def n_groups(self) -> int:
        return max(1, self.capacity_bits // self.group_bits)

    @property
    def n_mats(self) -> int:
        return self.n_groups * self.mats_per_group

    @property
    def n_subarrays(self) -> int:
        return self.n_mats * self.subarrays_per_mat

    def with_capacity(self, capacity_mb: int) -> "Geometry":
        return dataclasses.replace(self, capacity_mb=capacity_mb)

    def with_bus(self, bus_bits: int) -> "Geometry":
        return dataclasses.replace(self, bus_bits=bus_bits)
