"""Prices the mapper's operation counts in seconds and joules.

Latency: row-ops and program bursts run in parallel across the subarrays
that physically hold the layer's data (residency-limited width
``P = ceil(par_bits / subarray_bits)``, optionally boosted by the
replication factor when spare capacity allows duplicating operands — the
paper avoids duplication, so the default replication is 1).

Energy: per-op pricing from :mod:`repro.pim.device` plus static power
integrated over the runtime.
"""
from __future__ import annotations

import dataclasses
import math

from .device import NandSpinDevice, PeripheralCircuits
from .hierarchy import Geometry
from .mapper import OpCounts


@dataclasses.dataclass
class Cost:
    latency: float = 0.0
    energy: float = 0.0

    def __iadd__(self, o: "Cost") -> "Cost":
        self.latency += o.latency
        self.energy += o.energy
        return self


class CostModel:
    def __init__(
        self,
        geometry: Geometry,
        device: NandSpinDevice | None = None,
        periph: PeripheralCircuits | None = None,
    ):
        self.g = geometry
        self.dev = device or NandSpinDevice()
        self.per = periph or PeripheralCircuits()

    # -- widths -------------------------------------------------------------

    def parallel_width(self, oc: OpCounts) -> float:
        p = math.ceil(oc.par_bits / self.g.subarray_bits)
        return float(min(max(p, 1), self.g.n_subarrays))

    # -- primitive prices ----------------------------------------------------

    @property
    def e_and_rowop(self) -> float:
        return (self.g.cols * self.dev.and_energy_per_bit
                + self.per.bitcount_energy_per_op
                + self.per.decoder_energy_per_row_op)

    @property
    def e_read_rowop(self) -> float:
        return self.g.cols * self.dev.read_energy_per_bit + self.per.decoder_energy_per_row_op

    @property
    def e_program_step(self) -> float:
        # one row-program: up to 128 column-parallel STT switches
        return self.g.cols * self.dev.program_energy_per_bit

    @property
    def e_erase(self) -> float:
        return self.g.cols * self.dev.erase_energy_per_device

    def bus_time(self, bits: int) -> float:
        return bits / (self.g.bus_bits * self.per.bus_clock_hz)

    # -- phase pricing ---------------------------------------------------

    def price_rowops(self, oc: OpCounts) -> Cost:
        """Sense-path work: AND + bit-count + reads."""
        p = self.parallel_width(oc)
        rowops = oc.and_rowops + oc.read_rowops
        lat = max(rowops / p, float(oc.seq_floor)) * self.dev.and_latency
        e = oc.and_rowops * self.e_and_rowop + oc.read_rowops * self.e_read_rowop
        return Cost(lat, e)

    def price_programs(self, oc: OpCounts) -> Cost:
        """STT program bursts + SOT erases issued by this layer."""
        p = self.parallel_width(oc)
        lat = (oc.program_steps * self.dev.program_latency_per_bit
               + oc.erase_ops * self.dev.erase_latency_per_device) / p
        e = oc.program_steps * self.e_program_step + oc.erase_ops * self.e_erase
        return Cost(lat, e)

    def price_bus(self, oc: OpCounts) -> Cost:
        lat = self.bus_time(oc.bus_bits)
        e = (oc.bus_bits * self.per.bus_energy_per_bit
             + oc.buffer_bits * self.per.buffer_energy_per_bit)
        return Cost(lat, e)

    def price_local(self, oc: OpCounts) -> Cost:
        # In-mat movement rides private ports (§3.2), one per mat in parallel.
        lat = oc.local_bits / (self.g.bus_bits * self.per.bus_clock_hz * self.g.n_mats)
        return Cost(lat, oc.local_bits * self.per.local_bus_energy_per_bit)

    def static_energy(self, latency: float) -> float:
        return latency * self.per.static_power_per_mb * self.g.capacity_mb
