"""Prices the mapper's operation counts in seconds and joules.

Latency: row-ops and program bursts run in parallel across the subarrays
that physically hold the layer's data (residency-limited width
``P = ceil(par_bits / subarray_bits)``, optionally boosted by the
replication factor when spare capacity allows duplicating operands — the
paper avoids duplication, so the default replication is 1).

Energy: per-op pricing from :mod:`repro.pim.device` plus static power
integrated over the runtime.

Fault mitigation (repro.pim.faults) is charged here too: replicated MSB
planes multiply storage, sense work and programming; spare columns multiply
storage and programming. Pass a ``FaultConfig`` to :class:`CostModel` and
the per-phase prices scale by :func:`redundancy_factors` — None keeps every
price bit-identical to the unprotected model.
"""
from __future__ import annotations

import dataclasses
import math

from .device import NandSpinDevice, PeripheralCircuits
from .hierarchy import Geometry
from .mapper import OpCounts


def redundancy_factors(faults, w_bits: int, cols: int) -> dict:
    """Multiplicative overheads of the mitigation hierarchy (DESIGN.md §7).

    ``storage`` — stored bit-planes + spare columns vs. bare: the top
    ``protect_msb`` of ``w_bits`` planes each occupy ``vote_copies``
    subarrays, and ``spare_cols`` standby columns ride every subarray row.
    ``rowops``  — extra sense-path work: a protected plane is sensed once
    per stored copy, then majority-voted in the periphery.
    ``program`` — every redundant plane (and spare) programs its own cells.

    The column-sum checksum is free in storage: ``col_sums`` already exists
    as the affine correction's Sw register; the compare is digital periphery
    noise next to a row-op.
    """
    if faults is None:
        return {"storage": 1.0, "rowops": 1.0, "program": 1.0}
    p = min(faults.protect_msb, w_bits) / float(w_bits)
    red = 1.0 + p * (faults.vote_copies - 1)
    spares = faults.spare_cols / float(cols) if cols else 0.0
    return {"storage": red + spares, "rowops": red, "program": red + spares}


@dataclasses.dataclass
class Cost:
    latency: float = 0.0
    energy: float = 0.0

    def __iadd__(self, o: "Cost") -> "Cost":
        self.latency += o.latency
        self.energy += o.energy
        return self


class CostModel:
    def __init__(
        self,
        geometry: Geometry,
        device: NandSpinDevice | None = None,
        periph: PeripheralCircuits | None = None,
        faults=None,                 # FaultConfig: charge its mitigation
        w_bits: int = 8,
    ):
        self.g = geometry
        self.dev = device or NandSpinDevice()
        self.per = periph or PeripheralCircuits()
        self.red = redundancy_factors(faults, w_bits, geometry.cols)

    # -- widths -------------------------------------------------------------

    def parallel_width(self, oc: OpCounts) -> float:
        p = math.ceil(oc.par_bits / self.g.subarray_bits)
        return float(min(max(p, 1), self.g.n_subarrays))

    # -- primitive prices ----------------------------------------------------

    @property
    def e_and_rowop(self) -> float:
        return (self.g.cols * self.dev.and_energy_per_bit
                + self.per.bitcount_energy_per_op
                + self.per.decoder_energy_per_row_op)

    @property
    def e_read_rowop(self) -> float:
        return self.g.cols * self.dev.read_energy_per_bit + self.per.decoder_energy_per_row_op

    @property
    def e_program_step(self) -> float:
        # one row-program: up to 128 column-parallel STT switches
        return self.g.cols * self.dev.program_energy_per_bit

    @property
    def e_erase(self) -> float:
        return self.g.cols * self.dev.erase_energy_per_device

    def bus_time(self, bits: int) -> float:
        return bits / (self.g.bus_bits * self.per.bus_clock_hz)

    # -- phase pricing ---------------------------------------------------

    def price_rowops(self, oc: OpCounts) -> Cost:
        """Sense-path work: AND + bit-count + reads (x redundant copies)."""
        p = self.parallel_width(oc)
        f = self.red["rowops"]
        rowops = (oc.and_rowops + oc.read_rowops) * f
        lat = max(rowops / p, float(oc.seq_floor)) * self.dev.and_latency
        e = f * (oc.and_rowops * self.e_and_rowop
                 + oc.read_rowops * self.e_read_rowop)
        return Cost(lat, e)

    def price_programs(self, oc: OpCounts) -> Cost:
        """STT program bursts + SOT erases issued by this layer
        (x redundant planes + spares)."""
        p = self.parallel_width(oc)
        f = self.red["program"]
        lat = f * (oc.program_steps * self.dev.program_latency_per_bit
                   + oc.erase_ops * self.dev.erase_latency_per_device) / p
        e = f * (oc.program_steps * self.e_program_step
                 + oc.erase_ops * self.e_erase)
        return Cost(lat, e)

    def price_bus(self, oc: OpCounts) -> Cost:
        lat = self.bus_time(oc.bus_bits)
        e = (oc.bus_bits * self.per.bus_energy_per_bit
             + oc.buffer_bits * self.per.buffer_energy_per_bit)
        return Cost(lat, e)

    def price_local(self, oc: OpCounts) -> Cost:
        # In-mat movement rides private ports (§3.2), one per mat in parallel.
        lat = oc.local_bits / (self.g.bus_bits * self.per.bus_clock_hz * self.g.n_mats)
        return Cost(lat, oc.local_bits * self.per.local_bus_energy_per_bit)

    def static_energy(self, latency: float) -> float:
        return latency * self.per.static_power_per_mb * self.g.capacity_mb
