"""Analytical models of the comparison accelerators (paper Table 3, Figs. 14-15).

The paper compares against five published in-memory CNN accelerators. A
faithful reproduction needs their *behavior over the sweep axes* (model,
<W:I>), anchored to their published operating points — not five re-built
simulators. Each counterpart is modeled with three ingredients:

  1. Table 3 anchor: throughput (ResNet50-class, <8:8>) and die area.
  2. a workload law: time(model) ~ MACs + delta * weight_elems, where
     ``delta`` captures how expensive that technology's weight handling is
     relative to a MAC (DRAM row cycles, ReRAM programming, STT writes...).
  3. a precision law: time(<W:I>) grows with W*I plane pairs plus an
     accumulation term ``gamma * (W + I)`` — these designs accumulate
     partial sums with in-array adders whose chains grow with operand
     width, whereas ours bit-counts significant bits separately (§5.3
     point 4). PRIME instead is conversion-bound (input-serial + ADC).

``delta``/``gamma`` are fit (coarse grid, done once and cached) so each
counterpart matches BOTH its Table 3 point and the paper's §5.3 claimed
average speedup as closely as possible. Energy ratios are constructed to
match the §5.3 claimed averages exactly, with the same growth shaping.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import statistics

from .hierarchy import Geometry
from .simulator import simulate_model

# <W:I> sweep for Figs. 14-15 (8-bit is the deployment default per §4.2;
# 16-bit covers the "high-precision" end).
WI_CONFIGS = [(2, 2), (4, 4), (8, 8), (16, 16)]
MODELS = ["alexnet", "vgg19", "resnet50"]

_MACS = {"alexnet": 1.135e9, "vgg19": 19.632e9, "resnet50": 4.089e9}
_WEIGHTS = {"alexnet": 62.4e6, "vgg19": 143.7e6, "resnet50": 25.5e6}


@dataclasses.dataclass(frozen=True)
class Counterpart:
    name: str
    technology: str
    fps_t3: float          # Table 3 throughput (ResNet50, <8:8>)
    area_mm2: float        # Table 3
    speedup_claim: float   # §5.3 average speedup of ours over it
    energy_claim: float    # §5.3 average energy-efficiency ratio
    adc_bound: bool = False


COUNTERPARTS = [
    Counterpart("DRISA", "DRAM", 51.7, 117.2, 6.3, 2.3),
    Counterpart("PRIME", "ReRAM", 9.4, 78.2, 13.5, 12.3, adc_bound=True),
    Counterpart("STT-CiM", "STT-RAM", 45.6, 57.7, 2.6, 1.4),
    Counterpart("MRIMA", "STT-RAM", 52.3, 55.6, 2.6, 1.4),
    Counterpart("IMCE", "SOT-RAM", 21.8, 128.3, 5.1, 2.6),
]


@functools.lru_cache(maxsize=None)
def _ours(model: str, wb: int, ib: int):
    r = simulate_model(model, wb=wb, ab=ib)
    return r.fps, r.energy


def _precision_scale(c: Counterpart, gamma: float, phi: float,
                     wb: int, ib: int) -> float:
    """Time per inference relative to the <8:8> anchor.

    ``phi`` is the precision-independent fraction of the anchor runtime
    (row activation, data loading, pooling control — work that does not
    shrink with narrower operands; our own Fig. 16 breakdown shows ~40%
    of runtime in such phases). The precision-dependent remainder scales
    with the W*I plane pairs plus a width-dependent accumulation term."""
    if c.adc_bound:
        base = ib * (1 + 0.15 * (wb + math.log2(max(wb * ib, 2))))
        ref = 8 * (1 + 0.15 * (8 + 6))
        return phi + (1 - phi) * base / ref
    base = wb * ib * (1 + gamma * (wb + ib))
    return phi + (1 - phi) * base / (64 * (1 + gamma * 16))


def _workload_scale(delta: float, model: str) -> float:
    work = _MACS[model] + delta * _WEIGHTS[model]
    ref = _MACS["resnet50"] + delta * _WEIGHTS["resnet50"]
    return work / ref


def _avg_speedup(c: Counterpart, delta: float, gamma: float, phi: float,
                 our_area: float) -> float:
    vals = []
    for m in MODELS:
        for (wb, ib) in WI_CONFIGS:
            ours_pa = _ours(m, wb, ib)[0] / our_area
            fps = c.fps_t3 / (_workload_scale(delta, m)
                              * _precision_scale(c, gamma, phi, wb, ib))
            vals.append(ours_pa / (fps / c.area_mm2))
    return statistics.mean(vals)


@functools.lru_cache(maxsize=None)
def _fit(name: str) -> tuple[float, float, float]:
    """Grid-fit (delta, gamma, phi) to the §5.3 average-speedup claim.

    The Table 3 point is pinned by construction (fps_t3 at <8:8>/ResNet50);
    the fit only shapes how the counterpart degrades off-anchor."""
    from .area import chip_area_mm2

    c = next(x for x in COUNTERPARTS if x.name == name)
    our_area = chip_area_mm2(Geometry())
    best, best_err = (0.0, 0.1, 0.0), float("inf")
    for delta in [0, 1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048]:
        for gamma in [0.0, 0.05, 0.1, 0.2, 0.4, 0.8, 1.6, 3.2, 6.4, 12.8]:
            for phi in [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8]:
                err = abs(_avg_speedup(c, delta, gamma, phi, our_area)
                          - c.speedup_claim)
                if err < best_err:
                    best, best_err = (delta, gamma, phi), err
    return best


def counterpart_fps(c: Counterpart, model: str, wb: int, ib: int) -> float:
    delta, gamma, phi = _fit(c.name)
    return c.fps_t3 / (_workload_scale(delta, model)
                       * _precision_scale(c, gamma, phi, wb, ib))


def counterpart_energy_per_frame(c: Counterpart, model: str, wb: int, ib: int) -> float:
    """Energy shaped like the time law, normalized so the across-grid mean of
    (their energy / our energy) equals the paper's claimed ratio exactly."""
    delta, gamma, phi = _fit(c.name)
    shape = (_workload_scale(delta, model)
             * _precision_scale(c, gamma, phi, wb, ib))
    norm = statistics.mean(
        _workload_scale(delta, m) * _precision_scale(c, gamma, phi, *cfg)
        / _ours(m, *cfg)[1]
        for m in MODELS for cfg in WI_CONFIGS
    )
    return c.energy_claim * shape / norm


def speedup_table(geometry: Geometry | None = None) -> dict:
    """Per-area speedup of ours over each counterpart, per (model, config)."""
    from .area import chip_area_mm2

    g = geometry or Geometry()
    our_area = chip_area_mm2(g)
    table = {}
    for model in MODELS:
        for (wb, ib) in WI_CONFIGS:
            ours_pa = _ours(model, wb, ib)[0] / our_area
            for c in COUNTERPARTS:
                theirs_pa = counterpart_fps(c, model, wb, ib) / c.area_mm2
                table[(model, (wb, ib), c.name)] = ours_pa / theirs_pa
    return table


def energy_table() -> dict:
    table = {}
    for model in MODELS:
        for (wb, ib) in WI_CONFIGS:
            ours_e = _ours(model, wb, ib)[1]
            for c in COUNTERPARTS:
                theirs = counterpart_energy_per_frame(c, model, wb, ib)
                table[(model, (wb, ib), c.name)] = theirs / ours_e
    return table
