"""NAND-SPIN device-fault model + ECC-style mitigation (DESIGN.md §7).

The paper's cells are STT-MRAM devices: programming is stochastic (a write
burst leaves the MTJ in the wrong state with probability ``write_ber``),
reads disturb the stored state (``read_disturb_ber`` per sensed bit),
retention flips accumulate, and manufacturing leaves stuck-at cells and
occasionally whole dead subarrays. A shipped accelerator wraps the
bank/subarray hierarchy in redundancy; this module models both halves:

**Fault taxonomy → where it strikes.** Every weight bit lives in exactly one
bit-plane subarray (``PackedWeight.planes``), so all faults are expressed on
the per-bit-plane decomposition of the integer codes and rendered into
whatever representation a backend consumes (codes for int-direct/mxu-plane,
packed uint32 planes for popcount/pallas, the fused conv layout for the
implicit-im2col kernel) — the corrupted codes and corrupted planes always
describe the *same* device state, so cross-backend bit-parity survives
injection.

  * persistent (strike once, at subarray programming — :func:`inject_packed`):
    write errors, retention flips, stuck-at-0/1 cells, whole-subarray
    failures (a dead subarray reads all-zero for its column group).
  * transient (strike per read — :func:`read_disturb_scope` +
    :func:`disturb_packed` inside the bit-serial matmul path): read-disturb
    flips, freshly drawn from the PRNG key threaded through the hot loop.

**Mitigation → the paper's hierarchy.**

  * *Bit-plane-weighted protection*: Eq. 1 weighs plane ``m`` by ``2^m``, so
    an MSB flip costs exponentially more than an LSB flip. The top
    ``protect_msb`` weight planes are stored ``vote_copies`` times (each
    copy its own subarray) and majority-voted at the sense amps; the cheap
    planes stay bare. Modeled exactly: each copy is corrupted independently
    and the surviving plane is the bitwise majority.
  * *Column-sum checksum*: the prepack already stores ``col_sums`` (the
    affine correction's Sw) in the digital periphery; recomputing the sum
    from the stored planes and comparing flags any column whose codes
    changed — :func:`verify_columns`. (Sum-preserving flip pairs within one
    column escape; probability falls off quadratically in BER.)
  * *Spare remap + re-program*: :func:`repair_packed` remaps up to
    ``spare_cols`` flagged columns onto spare subarrays and re-programs them
    from the golden weights — in simulation, those columns are restored
    bit-exactly from the uncorrupted prepack.

Everything is pure ``jnp`` over ``jax.random`` (threefry), so injection is
value-deterministic: the same :class:`FaultConfig` + key produces
bit-identical corruption on one device or sharded across the
("data", "model") serving mesh, under jit, vmap (scan-stacked LM weights)
and shard_map alike. With faults disabled nothing here is ever traced —
the hot loops compile to the exact same HLO (asserted in
tests/test_faults.py).
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import bitslice
from repro.core.packed import (PackedConvWeight, PackedWeight,
                               repack_codes, repack_conv_codes)

# Key-derivation tags: one disjoint fold_in stream per fault mechanism.
_TAG_WRITE, _TAG_RETAIN, _TAG_DISTURB = 0x57, 0x52, 0x44
_TAG_STUCK0, _TAG_STUCK1, _TAG_SUBFAIL = 0x50, 0x51, 0x5F


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Device fault rates + mitigation knobs for one deployment.

    Rates are per-bit probabilities; ``subarray_fail_rate`` is per
    (bit-plane, column-group) — a failed subarray zeroes its whole extent.
    ``protect_msb`` counts weight planes from the MSB down that are stored
    ``vote_copies``-redundant and majority-voted. ``checksum`` arms the
    col_sums integrity probe; ``spare_cols`` bounds how many flagged
    columns :func:`repair_packed` may remap per weight matrix (the spare
    subarray budget).
    """

    write_ber: float = 0.0
    read_disturb_ber: float = 0.0
    retention_ber: float = 0.0
    stuck0_rate: float = 0.0
    stuck1_rate: float = 0.0
    subarray_fail_rate: float = 0.0
    subarray_cols: int = 128          # columns per subarray (Geometry.cols)
    seed: int = 0
    # -- mitigation -----------------------------------------------------
    protect_msb: int = 0
    vote_copies: int = 3
    checksum: bool = False
    spare_cols: int = 0

    @property
    def persistent(self) -> bool:
        """Any programming-time fault mechanism enabled?"""
        return (self.write_ber > 0 or self.retention_ber > 0
                or self.stuck0_rate > 0 or self.stuck1_rate > 0
                or self.subarray_fail_rate > 0)

    @property
    def transient(self) -> bool:
        """Per-read disturb enabled (needs a key threaded through the loop)?"""
        return self.read_disturb_ber > 0

    def key(self) -> jax.Array:
        return jax.random.PRNGKey(self.seed)


# ---------------------------------------------------------------------------
# Corruption core: everything on the (bits, K, N) plane decomposition
# ---------------------------------------------------------------------------

def _majority(vals: list) -> jax.Array:
    """Bitwise majority of an odd number of equal-shape int planes."""
    n = len(vals)
    if n == 1:
        return vals[0]
    acc = sum(v.astype(jnp.int32) for v in vals)
    return (acc > n // 2).astype(vals[0].dtype)


def _flip(key, rate: float, shape) -> jax.Array:
    if rate <= 0:
        return jnp.zeros(shape, jnp.int32)
    return jax.random.bernoulli(key, rate, shape).astype(jnp.int32)


def _subarray_mask(key, cfg: FaultConfig, k: int, n: int) -> jax.Array:
    """(K, N) 0/1 mask of cells inside failed subarrays (stuck-at-0)."""
    groups = -(-n // cfg.subarray_cols)
    hit = jax.random.bernoulli(key, cfg.subarray_fail_rate, (groups,))
    cols = jnp.repeat(hit, cfg.subarray_cols)[:n]
    return jnp.broadcast_to(cols[None, :], (k, n)).astype(jnp.int32)


def corrupt_codes(codes: jax.Array, bits: int, cfg: FaultConfig,
                  key: jax.Array) -> jax.Array:
    """Apply every persistent fault mechanism to (K, N) weight codes.

    Per plane ``b``: each stored copy independently picks up write +
    retention flips (XOR — a double flip self-cancels), then stuck-at and
    dead-subarray cells override whatever was written; protected planes
    majority-vote their copies. Returns int32 codes of the same shape.
    """
    k, n = codes.shape[-2], codes.shape[-1]
    out = jnp.zeros_like(codes)
    for b in range(bits):
        plane = (codes >> b) & 1
        copies = cfg.vote_copies if b >= bits - cfg.protect_msb else 1
        kb = jax.random.fold_in(key, b)
        stored = []
        for r in range(copies):
            kr = jax.random.fold_in(kb, r)
            v = plane
            v = v ^ _flip(jax.random.fold_in(kr, _TAG_WRITE),
                          cfg.write_ber, (k, n))
            v = v ^ _flip(jax.random.fold_in(kr, _TAG_RETAIN),
                          cfg.retention_ber, (k, n))
            s0 = _flip(jax.random.fold_in(kr, _TAG_STUCK0),
                       cfg.stuck0_rate, (k, n))
            if cfg.subarray_fail_rate > 0:
                s0 = s0 | _subarray_mask(
                    jax.random.fold_in(kr, _TAG_SUBFAIL), cfg, k, n)
            s1 = _flip(jax.random.fold_in(kr, _TAG_STUCK1),
                       cfg.stuck1_rate, (k, n))
            stored.append((v & (1 - s0)) | s1)
        out = out | (_majority(stored) << b)
    return out.astype(codes.dtype)


def transient_flip_field(shape_kn, bits: int, cfg: FaultConfig,
                         key: jax.Array) -> jax.Array:
    """(K, N) int32 XOR field of one read's disturb flips.

    Bit ``b`` of the field is set where plane ``b``'s sensed value flips
    this read. Protected planes sense all copies and vote, so their
    effective flip needs a majority of copies disturbed at once.
    """
    k, n = shape_kn
    field = jnp.zeros((k, n), jnp.int32)
    for b in range(bits):
        copies = cfg.vote_copies if b >= bits - cfg.protect_msb else 1
        kb = jax.random.fold_in(jax.random.fold_in(key, _TAG_DISTURB), b)
        flips = [_flip(jax.random.fold_in(kb, r), cfg.read_disturb_ber,
                       (k, n)) for r in range(copies)]
        field = field | (_majority(flips) << b)
    return field


# ---------------------------------------------------------------------------
# Rendering one code-space fault field into every packed representation
# ---------------------------------------------------------------------------

def inject_packed(pw, cfg: FaultConfig, key: jax.Array):
    """Persistent-fault injection at subarray programming time.

    Accepts a :class:`PackedWeight` or :class:`PackedConvWeight`; returns
    the same type with corrupted codes AND consistently corrupted planes
    (plus the fused conv layout), so every backend sees the same device
    state. Scan-stacked weights (leading reps axis on ``codes``) inject
    under ``vmap`` with per-rep keys.
    """
    if isinstance(pw, PackedConvWeight):
        return repack_conv_codes(
            pw, corrupt_codes(pw.mat.codes, pw.bits, cfg, key))
    if pw.codes.ndim == 3:              # vmap-prepacked LM scan stack
        keys = jax.random.split(key, pw.codes.shape[0])
        return jax.vmap(lambda p, k: inject_packed(p, cfg, k))(pw, keys)
    return repack_codes(pw, corrupt_codes(pw.codes, pw.bits, cfg, key))


def inject_tree(tree, cfg: FaultConfig | None, key: jax.Array | None = None):
    """Inject persistent faults into every packed leaf of a param tree.

    Each :class:`PackedWeight`/:class:`PackedConvWeight` gets its own key
    folded from a stable depth-first leaf counter, so adding unrelated
    leaves upstream does not re-roll an existing layer's faults only if the
    walk order is unchanged — good enough for a deployment artifact that is
    injected exactly once. When ``cfg.checksum`` is armed the flagged
    columns are immediately remapped to spares (bounded by
    ``cfg.spare_cols``) and re-programmed from the golden tree, modeling
    the deployment-time test-and-repair pass. Returns ``(tree, report)``.
    """
    if cfg is None or not cfg.persistent:
        return tree, {"injected": 0, "bad_cols": 0, "repaired_cols": 0}
    key = cfg.key() if key is None else key
    count = {"i": 0}
    report = {"injected": 0, "bad_cols": 0, "repaired_cols": 0}

    def walk(p):
        if isinstance(p, (PackedWeight, PackedConvWeight)):
            leaf_key = jax.random.fold_in(key, count["i"])
            count["i"] += 1
            bad = inject_packed(p, cfg, leaf_key)
            report["injected"] += 1
            if cfg.checksum:
                bad, n_bad, n_fix = repair_packed(bad, p, cfg.spare_cols,
                                                  cfg.subarray_cols)
                report["bad_cols"] += n_bad
                report["repaired_cols"] += n_fix
            return bad
        if isinstance(p, dict):
            return {k: walk(v) for k, v in p.items()}
        if isinstance(p, (list, tuple)):
            return type(p)(walk(v) for v in p)
        return p

    return walk(tree), report


# ---------------------------------------------------------------------------
# Checksum detection + spare-column repair
# ---------------------------------------------------------------------------

def verify_columns(pw) -> jax.Array:
    """Integrity probe: (..., N) bool mask of columns whose stored codes no
    longer sum to the periphery's golden ``col_sums`` (Sw register)."""
    if isinstance(pw, PackedConvWeight):
        pw = pw.mat
    return pw.codes.sum(-2).astype(jnp.int32) != pw.col_sums


def _repair_codes(codes, golden_codes, col_sums, spare_cols: int,
                  subarray_cols: int | None = None):
    bad = codes.sum(-2).astype(jnp.int32) != col_sums            # (..., N)
    badi = bad.astype(jnp.int32)
    if subarray_cols:
        # Spares are per-subarray hardware: a leaf spanning S column groups
        # of ``subarray_cols`` gets ``spare_cols`` repairs in *each* group,
        # not a flat leaf-wide budget.
        n = badi.shape[-1]
        pad = (-n) % subarray_cols
        grp = jnp.pad(badi, [(0, 0)] * (badi.ndim - 1) + [(0, pad)])
        grp = grp.reshape(*badi.shape[:-1], -1, subarray_cols)
        budget = (jnp.cumsum(grp, axis=-1) <= spare_cols).reshape(
            *badi.shape[:-1], -1)[..., :n]
    else:
        budget = jnp.cumsum(badi, axis=-1) <= spare_cols
    fix = bad & budget
    repaired = jnp.where(fix[..., None, :], golden_codes, codes)
    return repaired, bad.sum(), fix.sum()


def repair_packed(pw, golden, spare_cols: int,
                  subarray_cols: int | None = None):
    """Remap up to ``spare_cols`` checksum-flagged columns to spares and
    re-program them from the golden weights.

    Returns ``(repaired, n_bad, n_repaired)`` — counts as python ints (the
    call is an eager deployment-time pass, like prepack itself). With
    ``subarray_cols`` the budget applies per group of that many columns
    (each physical subarray carries its own spares); without it the budget
    is leaf-wide. Columns beyond the budget stay faulty.
    """
    if isinstance(pw, PackedConvWeight):
        codes, n_bad, n_fix = _repair_codes(
            pw.mat.codes, golden.mat.codes, pw.mat.col_sums, spare_cols,
            subarray_cols)
        return repack_conv_codes(pw, codes), int(n_bad), int(n_fix)
    codes, n_bad, n_fix = _repair_codes(
        pw.codes, golden.codes, pw.col_sums, spare_cols, subarray_cols)
    if pw.codes.ndim == 3:
        rebuilt = jax.vmap(repack_codes)(pw, codes)
    else:
        rebuilt = repack_codes(pw, codes)
    return rebuilt, int(n_bad), int(n_fix)


def repair_tree(tree, golden, spare_cols: int,
                subarray_cols: int | None = None):
    """Checksum-scan every packed leaf against its golden twin and remap
    flagged columns onto spares (per-subarray budget when ``subarray_cols``
    is given). Returns ``(repaired_tree, {"bad_cols", "repaired_cols"})`` —
    the field-service pass a deployment runs when the watchdog suspects
    silent corruption."""
    report = {"bad_cols": 0, "repaired_cols": 0}

    def walk(p, g):
        if isinstance(p, (PackedWeight, PackedConvWeight)):
            fixed, n_bad, n_fix = repair_packed(p, g, spare_cols,
                                                subarray_cols)
            report["bad_cols"] += n_bad
            report["repaired_cols"] += n_fix
            return fixed
        if isinstance(p, dict):
            return {k: walk(v, g[k]) for k, v in p.items()}
        if isinstance(p, (list, tuple)):
            return type(p)(walk(v, gv) for v, gv in zip(p, g))
        return p

    return walk(tree, golden), report


# ---------------------------------------------------------------------------
# Transient read disturb: scoped per hot-loop step, keyed per call site
# ---------------------------------------------------------------------------
# The idiom mirrors repro.distributed.sharding's process-global mesh scope:
# model code stays fault-agnostic, the engine activates the scope around its
# (traced) program body, and the bit-serial matmul entry points consult it.
# The key placed in the scope is a *tracer* when activation happens inside a
# jitted step — each pim_linear call site folds in a trace-time counter, so
# distinct projections draw distinct disturb fields and the per-step key
# threads fresh randomness into every decode step. Scan-stacked layers share
# one call site, hence one field per step (documented simplification).

_READ_CFG: FaultConfig | None = None
_READ_KEY = None
_READ_SITE = 0


@contextlib.contextmanager
def read_disturb_scope(cfg: FaultConfig | None, key):
    """Activate transient read-disturb for the programs traced inside."""
    global _READ_CFG, _READ_KEY, _READ_SITE
    if cfg is None or not cfg.transient:
        yield
        return
    prev = (_READ_CFG, _READ_KEY, _READ_SITE)
    _READ_CFG, _READ_KEY, _READ_SITE = cfg, key, 0
    try:
        yield
    finally:
        _READ_CFG, _READ_KEY, _READ_SITE = prev


def read_disturb_active() -> bool:
    return _READ_CFG is not None


def _site_key():
    global _READ_SITE
    k = jax.random.fold_in(_READ_KEY, _READ_SITE)
    _READ_SITE += 1
    return k


def disturb_packed(pw: PackedWeight) -> PackedWeight:
    """One read's disturbed view of a packed weight (scope must be active).

    Codes and planes are XOR-ed with the same flip field, so whichever
    representation the backend consumes sees the same disturbed bits; the
    unused rendering is dead code XLA eliminates. ``col_sums`` stays golden
    (periphery register — reads of it are digital).
    """
    cfg = _READ_CFG
    k = pw.codes.shape[-2]
    field = transient_flip_field((k, pw.codes.shape[-1]), pw.bits, cfg,
                                 _site_key())
    planes_mask = bitslice.slice_and_pack(field.T, pw.bits)
    pad = pw.planes.shape[-1] - planes_mask.shape[-1]
    if pad:
        planes_mask = jnp.pad(planes_mask, ((0, 0),) * (planes_mask.ndim - 1)
                              + ((0, pad),))
    return PackedWeight(codes=pw.codes ^ field.astype(pw.codes.dtype),
                        planes=pw.planes ^ planes_mask,
                        col_sums=pw.col_sums, wq=pw.wq, tune=pw.tune)


def disturb_fused_planes(fused: jax.Array, kernel_shape) -> jax.Array:
    """One read's disturbed view of a fused conv layout (scope active).

    The flip field is drawn in im2col code space — the exact shape the
    materialized path's :func:`disturb_packed` draws at the same site — so
    the fused implicit-im2col kernel and the im2col matmul see identical
    disturbed device state and stay bit-parity under injection.
    """
    cfg = _READ_CFG
    kh, kw, c, o = kernel_shape
    bits = fused.shape[1]
    field = transient_flip_field((kh * kw * c, o), bits, cfg, _site_key())
    ft = field.reshape(kh, kw, c, o).transpose(0, 3, 1, 2)   # (KH, O, KW, C)
    mask = bitslice.slice_and_pack(ft, bits).transpose(1, 0, 2, 3, 4)
    return fused ^ mask
