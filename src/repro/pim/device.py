"""Device/circuit-level constants (paper §5.1, Table 2 and measured results).

The paper's circuit characterization (45 nm PDK + LLG Verilog-A model,
Cadence Spectre/SPICE) reports, for one NAND-SPIN device of 8 MTJs:

  erase   180 fJ / device, ~0.3 ns per MTJ (SOT strip erase, all MTJs at once)
  program 840 fJ / device, 5 ns per bit   (STT AP->P, column-parallel per row)
  read    4.0 fJ / bit,    0.17 ns        (SPCSA sense; AND has the same path)

Counterpart technologies are characterized only as far as the comparison
figures need (baselines.py); their per-bit constants come from the cited
papers' own numbers and are tagged with provenance.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class NandSpinDevice:
    mtjs_per_device: int = 8

    erase_energy_per_device: float = 180e-15   # J, resets 8 MTJs
    erase_latency_per_mtj: float = 0.3e-9      # s (paper: "average 0.3 ns each")

    program_energy_per_device: float = 840e-15  # J for all 8 MTJs
    program_latency_per_bit: float = 5e-9       # s, one row-program step

    read_energy_per_bit: float = 4.0e-15        # J
    read_latency: float = 0.17e-9               # s per row operation
    and_energy_per_bit: float = 4.0e-15         # J (same sense path as read)
    and_latency: float = 0.17e-9                # s

    @property
    def erase_latency_per_device(self) -> float:
        return self.erase_latency_per_mtj * self.mtjs_per_device

    @property
    def program_energy_per_bit(self) -> float:
        return self.program_energy_per_device / self.mtjs_per_device


@dataclasses.dataclass(frozen=True)
class PeripheralCircuits:
    """45 nm peripheral constants (bit-counter synthesized with DC, §5.1).

    The paper does not publish the synthesized numbers; these are set to
    representative 45 nm values and participate in the calibration described
    in :mod:`repro.pim.calibrate` (the calibrated model reproduces the
    paper's Fig. 16 breakdown and Table 3 throughput).
    """

    bitcount_energy_per_op: float = 120e-15   # J per 128-bit count-accumulate
    bitcount_latency: float = 0.0             # pipelined behind the AND row op
    buffer_energy_per_bit: float = 10e-15     # J, SRAM weight buffer write/read
    bus_energy_per_bit: float = 2e-12         # J, global bus (NVSim-class 45nm)
    local_bus_energy_per_bit: float = 0.5e-12 # J, in-mat movement
    bus_clock_hz: float = 1.0e9               # 128-bit bus @ 1 GHz
    decoder_energy_per_row_op: float = 30e-15 # J, row/col decode per access
    static_power_per_mb: float = 0.25e-3      # W, controllers/charge pumps etc.
