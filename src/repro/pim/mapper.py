"""Layer -> subarray operation counts (the paper's mapping scheme, §4).

Every layer expands into the NAND-SPIN micro-operations its schedule would
issue, following Fig. 8 (bitwise convolution), Fig. 9 (addition), Fig. 10
(multiplication), Fig. 11 (comparison) and the Fig. 12 layer pipeline:

  and_rowops      one 128-column sense-amp AND + bit-count per weight-plane
                  row step
  read_rowops     plain row reads (operand fetch for add/mul/compare)
  program_steps   5 ns STT program steps, 128-column parallel (count
                  write-backs, product/sum bits, activation stores)
  erase_ops       SOT strip erases preceding program bursts
  bus_bits        global bus traffic (weight broadcast, initial input)
  buffer_bits     SRAM weight-buffer writes
  local_bits      in-mat movement (cross-written counts)

Parallelism is *residency-limited* (the paper minimizes data duplication,
§4.2): a layer's row-ops can only run in subarrays that physically hold its
operands, so each count carries the tensor footprint that bounds its
parallel width (`par_bits` = bits of resident data the phase fans out over).
"""
from __future__ import annotations

import dataclasses
import math

from repro.models.cnn.specs import GemmSpec

from .hierarchy import Geometry


@dataclasses.dataclass
class OpCounts:
    and_rowops: int = 0
    read_rowops: int = 0
    program_steps: int = 0
    erase_ops: int = 0
    bus_bits: int = 0
    buffer_bits: int = 0
    local_bits: int = 0
    par_bits: int = 1        # resident-data footprint bounding parallelism
    seq_floor: int = 0       # minimum sequential row-ops (critical path)


def _count_bits(k: int) -> int:
    return max(1, math.ceil(math.log2(k + 1)))


def map_gemm(spec: GemmSpec, g: Geometry, ab: int, wb: int) -> OpCounts:
    """Convolution / FC via the Fig. 8 schedule.

    The input lives once (ab bit-planes); weights stream from buffers. For
    each (plane pair, output channel, 128-column output batch) the K
    contraction rows are sensed serially while 128 bit-counters accumulate;
    the count is then cross-written (cb bits vertically) into the
    accumulator subarray, and a Fig. 9 addition folds the ab*wb counts.
    """
    # Output elements (m positions x n channels) tile the 128 bit-counter
    # columns; each group accumulates its K contraction serially. FC (m=1)
    # therefore still fills whole column groups with output channels — the
    # paper's "FC as 1x1 conv" mapping.
    out_groups = math.ceil(spec.m * spec.n / g.cols)
    cb = _count_bits(spec.k)
    pairs = ab * wb
    oc = OpCounts()
    oc.and_rowops = pairs * spec.k * out_groups
    writebacks = pairs * out_groups
    oc.program_steps = writebacks * cb
    oc.erase_ops = writebacks
    oc.local_bits = writebacks * cb * g.cols
    # Fig. 9 addition over the pairs (weighted by 2^(n+m) via row placement):
    add_bits = cb + math.ceil(math.log2(pairs)) + 1
    adds = out_groups
    oc.read_rowops += adds * pairs  # read each operand bit-position group
    oc.program_steps += adds * add_bits
    oc.erase_ops += adds
    # Output activations stored for the next layer (re-quantized to ab bits).
    out_rows = math.ceil(spec.out_elems * ab / (g.cols * 8))
    oc.program_steps += out_rows * 8
    oc.erase_ops += out_rows
    # Stationary weights: broadcast once, reused across the whole plane sweep.
    oc.bus_bits = spec.weight_elems * wb
    oc.buffer_bits = spec.weight_elems * wb
    # Parallelism is bounded by whichever operand is resident across
    # subarrays — input planes for conv, the weight matrix for FC.
    oc.par_bits = max(spec.in_elems * ab, spec.out_elems * ab,
                      spec.weight_elems * wb)
    oc.seq_floor = pairs * spec.k
    return oc


def map_pool_max(spec: GemmSpec, g: Geometry, ab: int) -> OpCounts:
    """Iterative comparison (Fig. 11): per bit, ~2 reads + 2 ANDs + tag/result
    updates (2 program steps), MSB -> LSB, per window reduction step."""
    comparisons = spec.out_elems * max(1, spec.window - 1)
    col_batches = math.ceil(comparisons / g.cols)
    oc = OpCounts()
    oc.and_rowops = col_batches * ab * 2
    oc.read_rowops = col_batches * ab * 2
    oc.program_steps = col_batches * ab * 2
    oc.erase_ops = col_batches * 2
    # winner selectively copied to the next layer's operand rows
    out_rows = math.ceil(spec.out_elems * ab / (g.cols * 8))
    oc.program_steps += out_rows * 8
    oc.erase_ops += out_rows
    oc.local_bits = spec.out_elems * ab
    oc.par_bits = spec.in_elems * ab
    oc.seq_floor = ab * 6 * max(1, spec.window - 1)
    return oc


def map_pool_avg(spec: GemmSpec, g: Geometry, ab: int) -> OpCounts:
    """Fig. 9 addition over the window + Fig. 10 multiply by 1/window."""
    col_batches = math.ceil(spec.out_elems / g.cols)
    sum_bits = ab + _count_bits(spec.window)
    oc = OpCounts()
    oc.read_rowops = col_batches * spec.window * ab
    oc.and_rowops = col_batches * ab * ab
    oc.program_steps = col_batches * (sum_bits + 2 * ab)
    oc.erase_ops = col_batches * 2
    oc.par_bits = spec.in_elems * ab
    oc.seq_floor = spec.window * ab + ab * ab
    return oc


def map_affine(spec: GemmSpec, g: Geometry, ab: int) -> OpCounts:
    """BN (Eq. 3) / quantization (Eq. 2): Fig. 10 multiply + Fig. 9 add.

    Per 128-column batch: the multiply runs 2*ab bit-position steps, each
    reading operand rows, counting, writing the product bit back and
    right-shifting the carries (program-heavy, 5 ns steps)."""
    col_batches = math.ceil(spec.out_elems / g.cols)
    oc = OpCounts()
    oc.and_rowops = col_batches * ab * ab          # bit-products
    oc.read_rowops = col_batches * 2 * ab          # operand/carry reads
    oc.program_steps = col_batches * (2 * ab + ab) # product bits + sum bits
    oc.erase_ops = col_batches * 2
    oc.par_bits = spec.out_elems * ab
    oc.seq_floor = 2 * ab * (ab + 2)
    return oc


def map_relu(spec: GemmSpec, g: Geometry, ab: int) -> OpCounts:
    oc = OpCounts()
    oc.read_rowops = math.ceil(spec.out_elems / g.cols)
    oc.program_steps = math.ceil(spec.out_elems * ab / g.cols / 2)
    oc.erase_ops = math.ceil(spec.out_elems / g.cols / 2)
    oc.par_bits = spec.out_elems * ab
    oc.seq_floor = 2
    return oc


def map_layer(spec: GemmSpec, g: Geometry, ab: int, wb: int) -> tuple[str, OpCounts]:
    """Returns (phase, counts); phases follow the paper's Fig. 16 split."""
    if spec.kind in ("conv", "fc"):
        return "conv", map_gemm(spec, g, ab, wb)
    if spec.kind == "pool_max":
        return "pool", map_pool_max(spec, g, ab)
    if spec.kind == "pool_avg":
        return "pool", map_pool_avg(spec, g, ab)
    if spec.kind == "bn":
        return "bn", map_affine(spec, g, ab)
    if spec.kind == "quant":
        return "quant", map_affine(spec, g, ab)
    if spec.kind == "act":
        return "bn", map_relu(spec, g, ab)
    raise ValueError(f"unknown layer kind {spec.kind}")
