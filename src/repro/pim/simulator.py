"""Architecture-level simulator (the paper's §5.1 in-house simulator).

Walks a CNN layer-spec list and prices every layer's data movement and
in-memory computation. Phases follow Fig. 16:

  load       weight broadcast + buffer fill + initial input programming
  conv       AND/bit-count row-ops + count write-backs + Fig. 9 fold +
             output activation stores
  transfer   in-mat movement of cross-written counts
  pool       comparison / window-addition work
  bn, quant  in-memory affine passes
"""
from __future__ import annotations

import dataclasses

from repro.models.cnn.specs import GemmSpec, model_specs

from .calibrate import Calibration
from .cost_model import Cost, CostModel
from .device import NandSpinDevice, PeripheralCircuits
from .hierarchy import Geometry
from .mapper import OpCounts, map_layer

PHASES = ("load", "conv", "transfer", "pool", "bn", "quant")


@dataclasses.dataclass
class SimResult:
    phases: dict
    latency: float
    energy: float
    fps: float
    geometry: Geometry
    ab: int
    wb: int

    @property
    def latency_breakdown(self) -> dict:
        return {p: c.latency / self.latency for p, c in self.phases.items()}

    @property
    def energy_breakdown(self) -> dict:
        dyn = sum(c.energy for c in self.phases.values())
        return {p: c.energy / dyn for p, c in self.phases.items()}

    @property
    def efficiency_fps_per_w(self) -> float:
        return self.fps / (self.energy * self.fps)  # = 1 / energy-per-frame


def simulate(
    specs: list[GemmSpec],
    geometry: Geometry | None = None,
    ab: int = 8,
    wb: int = 8,
    device: NandSpinDevice | None = None,
    periph: PeripheralCircuits | None = None,
    util: Calibration | None = None,
) -> SimResult:
    g = geometry or Geometry()
    if util is None:
        from .calibrate import calibrated

        util = calibrated()
    cm = CostModel(g, device, periph)
    phases = {p: Cost() for p in PHASES}

    # Initial image enters over the global bus and is programmed into CMs.
    first = next(s for s in specs if s.kind in ("conv", "fc"))
    in_bits = first.in_elems * ab
    iw = OpCounts(program_steps=in_bits // g.cols, erase_ops=in_bits // (g.cols * 8),
                  bus_bits=in_bits, par_bits=in_bits)
    phases["load"] += cm.price_programs(iw)
    phases["load"] += cm.price_bus(iw)

    for spec in specs:
        phase, oc = map_layer(spec, g, ab, wb)
        rowops = cm.price_rowops(oc)
        programs = cm.price_programs(oc)
        bus = cm.price_bus(oc)
        local = cm.price_local(oc)
        # Weight broadcast & buffering belong to the load phase and overlap
        # across layers (double-buffered), but serialize on the shared bus.
        phases["load"] += bus
        phases[phase] += rowops
        phases[phase] += programs
        phases["transfer"] += local

    scaled = {
        p: Cost(c.latency * util.lat[p], c.energy * util.energy[p])
        for p, c in phases.items()
    }
    latency = sum(c.latency for c in scaled.values())
    energy = sum(c.energy for c in scaled.values()) + cm.static_energy(latency)
    return SimResult(phases=scaled, latency=latency, energy=energy,
                     fps=1.0 / latency, geometry=g, ab=ab, wb=wb)


def simulate_model(model: str, batch: int = 1, image: int = 224, **kw) -> SimResult:
    return simulate(model_specs(model, batch=batch, image=image), **kw)


def peak_gops(g: Geometry, cm: CostModel | None = None) -> float:
    """Peak bit-op throughput: every subarray senses one 128-column row per
    AND latency; 2 ops per column (AND + count-accumulate)."""
    cm = cm or CostModel(g)
    return g.n_subarrays * g.cols * 2 / cm.dev.and_latency / 1e9
