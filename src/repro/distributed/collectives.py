"""Distributed-optimization tricks: gradient compression & overlap hooks.

Int8 gradient compression with error feedback (1-bit-Adam family): each
gradient leaf is scaled to int8, the quantization residual is carried in a
persistent error-feedback buffer and re-added next step — unbiased in the
long run, 4x less cross-pod traffic. Used for the *pod* axis (pure DP,
rides the slowest links); in-pod FSDP reduce-scatters stay full precision.

Under GSPMD the cross-pod sum happens implicitly during backward, so the
compression here is applied where it is explicit and correct for any
sharding: simulate-compress the summed gradient (quantize + dequantize +
error feedback). The *traffic* saving on real DCN additionally needs the
collective itself to run on int8 — that variant is provided as
``compressed_psum`` for shard_map-based pod reductions and exercised in
tests on a CPU mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    bits: int = 8


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(g: jax.Array, err: jax.Array, bits: int = 8):
    """Quantize g+err per-leaf symmetric int<bits>; return (g_hat, new_err)."""
    gf = g.astype(jnp.float32) + err
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(gf)) / qmax + 1e-30
    q = jnp.clip(jnp.round(gf / scale), -qmax, qmax)
    g_hat = q * scale
    return g_hat, gf - g_hat


def make_grad_compressor(cfg: CompressionConfig):
    """Pytree-level wrapper used by the train step (error feedback threaded
    through opt_state by the caller via closure state)."""
    if not cfg.enabled:
        return None

    def compress(grads, err_tree):
        out = jax.tree.map(
            lambda g, e: compress_decompress(g, e, cfg.bits), grads, err_tree)
        g_hat = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        return g_hat, new_err

    return compress


def compressed_psum(x: jax.Array, axis_name: str, bits: int = 8) -> jax.Array:
    """int8-on-the-wire psum for shard_map pod reductions.

    Quantizes locally, sums the int values (exact in int32 for <=2^23/qmax
    participants), then dequantizes with the max of the per-participant
    scales — a standard all-reduce-compatible compression scheme.
    """
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(x)) / qmax + 1e-30
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    qsum = jax.lax.psum(q, axis_name)
    smax = jax.lax.pmax(scale, axis_name)
    return qsum.astype(jnp.float32) * smax
