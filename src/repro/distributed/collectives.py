"""Distributed-optimization tricks: gradient compression & overlap hooks.

Int8 gradient compression with error feedback (1-bit-Adam family): each
gradient leaf is scaled to int8, the quantization residual is carried in a
persistent error-feedback buffer and re-added next step — unbiased in the
long run, 4x less cross-pod traffic. Used for the *pod* axis (pure DP,
rides the slowest links); in-pod FSDP reduce-scatters stay full precision.

Under GSPMD the cross-pod sum happens implicitly during backward, so the
compression here is applied where it is explicit and correct for any
sharding: simulate-compress the summed gradient (quantize + dequantize +
error feedback). The *traffic* saving on real DCN additionally needs the
collective itself to run on int8 — that variant is provided as
``compressed_psum`` for shard_map-based pod reductions and exercised in
tests on a CPU mesh.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    bits: int = 8


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(g: jax.Array, err: jax.Array, bits: int = 8):
    """Quantize g+err per-leaf symmetric int<bits>; return (g_hat, new_err)."""
    gf = g.astype(jnp.float32) + err
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(gf)) / qmax + 1e-30
    q = jnp.clip(jnp.round(gf / scale), -qmax, qmax)
    g_hat = q * scale
    return g_hat, gf - g_hat


def make_grad_compressor(cfg: CompressionConfig):
    """Pytree-level wrapper used by the train step (error feedback threaded
    through opt_state by the caller via closure state)."""
    if not cfg.enabled:
        return None

    def compress(grads, err_tree):
        out = jax.tree.map(
            lambda g, e: compress_decompress(g, e, cfg.bits), grads, err_tree)
        g_hat = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        return g_hat, new_err

    return compress


def shard_map_compat(f, mesh, in_specs, out_specs, check_rep: bool = True):
    """jax.shard_map when available (>=0.6), else the experimental one —
    the same compat split `distributed.pipeline` uses.

    ``check_rep=False`` disables the replication/vma checker — required for
    bodies containing ``pallas_call`` (no replication rule registered)."""
    if hasattr(jax, "shard_map"):
        # The checker kwarg was renamed check_rep -> check_vma across jax
        # versions; try both spellings. When neither is accepted, fall back
        # to the default only if the caller did not need the checker OFF —
        # bodies like pallas_call have no replication rule, and tracing
        # them with checking enabled fails with an opaque error.
        for kw in ({"check_vma": check_rep}, {"check_rep": check_rep}):
            try:
                return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, **kw)
            except TypeError:
                continue
        if check_rep:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)
        raise RuntimeError(
            "this jax version's shard_map accepts neither check_vma nor "
            "check_rep; cannot disable the replication checker this body "
            "requires")
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep)


def exact_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Lossless cross-shard partial-sum reduction — the paper's
    cross-subarray accumulation as a mesh collective.

    The bit-serial kernels emit int32 popcount partial sums per shard when
    the packed contraction (K words) is split across a mesh axis
    (``kernels.bitserial_matmul.bitserial_matmul_sharded``); int32 addition
    is associative mod 2^32, so unlike :func:`compressed_psum` there is no
    quantize/dequantize step and cross-shard results are bit-identical to
    the single-device kernel. Kept here so serving's shard_map kernels and
    training's pod reductions share one reduction seam."""
    return jax.lax.psum(x, axis_name)


def compressed_psum(x: jax.Array, axis_name: str, bits: int = 8) -> jax.Array:
    """int8-on-the-wire psum for shard_map pod reductions.

    Quantizes locally, sums the int values (exact in int32 for <=2^23/qmax
    participants), then dequantizes with the max of the per-participant
    scales — a standard all-reduce-compatible compression scheme.
    """
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(x)) / qmax + 1e-30
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    qsum = jax.lax.psum(q, axis_name)
    smax = jax.lax.pmax(scale, axis_name)
    return qsum.astype(jnp.float32) * smax
