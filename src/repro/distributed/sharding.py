"""Sharding rules: parameter/optimizer/activation/state PartitionSpecs.

Axis semantics on the production mesh (see ``repro.launch.mesh``):

  pod    pure data parallelism across pods (gradient all-reduce via ICI/DCN)
  data   FSDP: batch sharding for activations AND parameter/optimizer-state
         sharding (ZeRO-3 style) — params gather on use, grads reduce-scatter
  model  tensor parallelism: attention heads / FFN hidden / expert dim

Rules are name-based (we own every init function, so names are total) with
a divisibility guard: any rule axis that does not divide the corresponding
dimension is dropped (replicated) rather than relying on GSPMD padding —
keeps the dry-run portable and the collective schedule predictable.

MoE experts: the expert dim shards on "model" when it divides the axis
(phi3.5: 16e on 16-way TP = pure expert parallelism); otherwise the expert
FFN hidden dim shards instead (grok: 8e -> TP inside every expert).
"""
from __future__ import annotations

import logging

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_log = logging.getLogger(__name__)

# ---------------------------------------------------------------------------
# Mesh context (set by launchers; model code stays mesh-agnostic)
# ---------------------------------------------------------------------------

_MESH: Mesh | None = None
_TIED = False
_SERVE_LAYOUT = False
_CNN_SERVE_LAYOUT = False


def set_mesh(mesh: Mesh | None):
    global _MESH
    _MESH = mesh


def set_cnn_serve_layout(on: bool):
    """Select the CNN serving layout (conv banks on "model", DESIGN.md §6)
    for the at-use constraints ``constrain_cnn_conv_input``/``_output``
    inside ``pim_conv2d``. ``VisionEngine`` scopes this (with the mesh)
    around its forward calls; training/dry-run traces never see it."""
    global _CNN_SERVE_LAYOUT
    _CNN_SERVE_LAYOUT = bool(on)


def get_cnn_serve_layout() -> bool:
    return _CNN_SERVE_LAYOUT


def set_serve_layout(on: bool):
    """Select the serving KV-cache layout (heads on "model", DESIGN.md §5)
    for at-use constraints like ``constrain_kv_update`` — the training
    layout shards the KV *sequence* instead. ``ServeEngine`` scopes this
    (with the mesh) around its program calls."""
    global _SERVE_LAYOUT
    _SERVE_LAYOUT = bool(on)


def get_serve_layout() -> bool:
    return _SERVE_LAYOUT


def set_tied_embeddings(tied: bool):
    """Tied-embedding models keep vocab on the TP axis (the lm_head matmul
    wants it); untied models shard vocab on FSDP only (cheap token gather)."""
    global _TIED
    _TIED = tied


def get_mesh() -> Mesh | None:
    return _MESH


def dp_axes(mesh: Mesh) -> tuple:
    """Axes that shard the batch (pure DP + FSDP axes)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, *axes) -> int:
    out = 1
    for a in axes:
        if a in mesh.axis_names:
            out *= mesh.shape[a]
    return out


def constrain(x, spec: P):
    """with_sharding_constraint if a mesh is active, else identity."""
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def constrain_batch(x, batch_dim: int = 0):
    """Pin an activation's batch dim to the DP axes (identity without mesh).

    GSPMD occasionally replicates the batch through scan carries when a
    badly-sharded producer (e.g. a vocab-sharded embedding gather) feeds the
    loop — a silent n_data x compute blowup that this constraint prevents.
    Skipped when the batch does not divide the DP axes (long_500k's B=1).
    """
    if _MESH is None:
        return x
    dp = dp_axes(_MESH)
    if not dp or x.shape[batch_dim] % axis_size(_MESH, *dp) != 0:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = dp
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*spec)))


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# name -> spec template over the *logical* dims of that parameter.
# "fsdp" -> data axis, "tp" -> model axis, None -> replicated dim.
_PARAM_RULES = {
    # embeddings / head. The untied embedding shards vocab on FSDP only:
    # a TP-sharded vocab makes the token gather reshard through a full
    # rematerialization (measured in the grok §Perf iterations). Tied
    # embeddings switch back to vocab-on-TP via ``set_tied_embeddings``.
    "embed": (None, "fsdp"),          # (vocab, d)
    "head": ("fsdp", "tp"),           # (d, vocab)
    # attention
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    "q_norm": (None,), "k_norm": (None,), "gate": (),
    # mlp
    "w_in": ("fsdp", "tp"),
    "w_gate": ("fsdp", "tp"),
    "w_out": ("tp", "fsdp"),
    # moe (3D expert weights handled specially below)
    "router": ("fsdp", None),
    # rglru
    "w_x": ("fsdp", "tp"),
    "conv": (None, "tp"),
    "w_a": ("fsdp", "tp"),
    "w_i": ("fsdp", "tp"),
    "b_a": ("tp",), "b_i": ("tp",), "lam": ("tp",),
    # rwkv
    "w_r": ("fsdp", "tp"),
    "w_k": ("fsdp", "tp"),
    "w_v": ("fsdp", "tp"),
    "w_g": ("fsdp", "tp"),
    "w_o": ("tp", "fsdp"),
    "decay_a": ("fsdp", None),
    "decay_b": (None, "fsdp"),
    "w0": (None,), "mu": (None, None), "u": (None, None), "ln_scale": (None, None),
    # norms
    "scale": (None,), "bias": (None,),
}

_MOE_3D = {"w_in", "w_gate", "w_out"}


def _axis_for(tag, mesh: Mesh):
    if tag == "fsdp":
        # Multi-pod: params/optimizer shard across pods too (ZeRO across the
        # full fleet); the cross-pod all-gather overlaps with compute.
        if "pod" in mesh.axis_names and "data" in mesh.axis_names:
            return ("pod", "data")
        return "data" if "data" in mesh.axis_names else None
    if tag == "tp":
        return "model" if "model" in mesh.axis_names else None
    return None


# (label, axis, dim, size) tuples already reported — the guard drops axes
# during every tree_map over every leaf, so an unthrottled warning would
# print thousands of identical lines for one misconfigured mesh.
_warned_drops: set = set()


def reset_drop_warnings():
    """Clear the warn-once cache (tests; or after switching meshes)."""
    _warned_drops.clear()


def _warn_drop(label: str, ax, dim: int, sz: int):
    key = (label, str(ax), int(dim), int(sz))
    if key in _warned_drops:
        return
    _warned_drops.add(key)
    _log.warning(
        "sharding: %s dim %d not divisible by mesh axis %r (size %d) — "
        "dropping to replication; this leaf will not shard on this mesh",
        label or "<leaf>", dim, ax, sz)


def _guard(spec_axes: tuple, shape: tuple, mesh: Mesh, label: str = "") -> P:
    """Drop axes that don't divide the dim; pad spec to the leaf's rank.

    Each drop of a *real* axis (mesh size > 1) logs a one-time warning so a
    misconfigured mesh (nothing actually sharding) is visible instead of
    silently replicating everything."""
    spec = list(spec_axes) + [None] * (len(shape) - len(spec_axes))
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        sz = axis_size(mesh, *axes)
        if sz > 1 and dim % sz == 0:
            out.append(ax)
        else:
            if sz > 1:
                _warn_drop(label, ax, dim, sz)
            out.append(None)
    return P(*out)


def _param_spec(path, leaf, mesh: Mesh, n_experts: int | None) -> P:
    names = [k.key for k in path if hasattr(k, "key")]
    name = names[-1] if names else ""
    stacked = "scan" in names  # scan-stacked params carry a leading reps axis
    in_moe = "ffn" in names and leaf.ndim - (1 if stacked else 0) == 3

    if in_moe and name in _MOE_3D:
        # (E, d, f) or (E, f, d): expert-parallel when E divides the TP axis,
        # else TP inside each expert on the f dim.
        tp = axis_size(mesh, "model")
        e = leaf.shape[1 if stacked else 0]
        if tp > 1 and e % tp == 0:
            spec = ("tp", "fsdp", None) if name != "w_out" else ("tp", None, "fsdp")
        else:
            spec = (None, "fsdp", "tp") if name != "w_out" else (None, "tp", "fsdp")
    elif "channel_mix" in names and name == "w_v":
        spec = ("tp", "fsdp")          # rwkv channel-mix down-proj is (f, d)
    elif name == "embed" and _TIED:
        spec = ("tp", "fsdp")
    elif name in _PARAM_RULES:
        spec = _PARAM_RULES[name]
    else:
        spec = tuple(None for _ in leaf.shape)

    spec = tuple(_axis_for(t, mesh) for t in spec)
    if stacked:
        spec = (None,) + spec
        shape = leaf.shape
    else:
        shape = leaf.shape
    return _guard(spec, shape, mesh, label=f"param:{name}")


def param_shardings(params_tree, mesh: Mesh, n_experts: int | None = None):
    """Map a param pytree (arrays or ShapeDtypeStructs) -> NamedShardings."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, _param_spec(p, l, mesh, n_experts)),
        params_tree)


# ---------------------------------------------------------------------------
# Batch / state rules
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, global_batch: int, rank: int = 2) -> P:
    """Tokens/labels (B, S, ...) — batch over DP axes when divisible."""
    dp = dp_axes(mesh)
    if dp and global_batch % axis_size(mesh, *dp) == 0:
        return P(dp, *(None,) * (rank - 1))
    return P(*(None,) * rank)


def batch_shardings(batch_tree, mesh: Mesh, global_batch: int):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(mesh, global_batch, l.ndim)),
        batch_tree)


def _state_spec(path, leaf, mesh: Mesh, global_batch: int) -> P:
    names = [k.key for k in path if hasattr(k, "key")]
    name = names[-1] if names else ""
    dp = dp_axes(mesh)
    b_ok = dp and global_batch % axis_size(mesh, *dp) == 0
    # Layer states may be scan-stacked (leading reps axis) — detect by rank.
    if name in ("k", "v"):
        # KV cache (B, S, H, hd): shard the SEQUENCE on the TP axis
        # (flash-decoding layout) — every model shard owns a contiguous
        # KV chunk, attention softmax combines via tiny partial-stat
        # all-reduces, and the per-token scatter update lands on one
        # shard. Sharding head_dim instead forced whole-cache gathers
        # (measured: 28 GB/step on llama decode_32k, §Perf).
        seq_dim = len(leaf.shape) - 3
        seq_ok = leaf.shape[seq_dim] % axis_size(mesh, "model") == 0
        spec = (dp if b_ok else None, "model" if seq_ok else None, None, None)
    elif name in ("k_scale", "v_scale"):   # int8 KV scales (B, S, H)
        seq_ok = leaf.shape[-2] % axis_size(mesh, "model") == 0
        spec = (dp if b_ok else None, "model" if seq_ok else None, None)
    elif name == "wkv":          # (B, H, D, D)
        spec = (dp if b_ok else None, None, None, None)
    elif name in ("tm_shift", "cm_shift", "h"):   # (B, d)
        spec = (dp if b_ok else None, "model")
    elif name == "conv":         # (B, K-1, W)
        spec = (dp if b_ok else None, None, "model")
    elif name == "length":
        return P()
    else:
        spec = tuple(None for _ in leaf.shape)
    if len(spec) < leaf.ndim:    # stacked: prepend None for the reps axis
        spec = (None,) * (leaf.ndim - len(spec)) + tuple(spec)
    return _guard(tuple(spec), leaf.shape, mesh, label=f"state:{name}")


def state_shardings(state_tree, mesh: Mesh, global_batch: int):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, _state_spec(p, l, mesh, global_batch)),
        state_tree)


def constrain_kv_update(k_new):
    """Pin a multi-token KV update (B, S_new, H, hd) to the cache's layout
    BEFORE the scatter — otherwise GSPMD reshards the whole prefill KV
    through the scatter (measured: 2-5x collective-term regressions on
    prefill cells).

    Training/dry-run layout: batch on DP, sequence on TP (flash-decoding).
    Serving layout (``set_serve_layout``): *heads* on TP, matching
    ``serve_state_shardings`` — pinning the training layout here instead
    would force a reshard against the heads-split serving cache on every
    admission chunk."""
    if _MESH is None or k_new.ndim != 4 or k_new.shape[1] == 1:
        return k_new
    dp = dp_axes(_MESH)
    b_ok = dp and k_new.shape[0] % axis_size(_MESH, *dp) == 0
    tp = axis_size(_MESH, "model")
    if _SERVE_LAYOUT:
        heads_ok = tp > 1 and k_new.shape[2] % tp == 0
        spec = P(dp if b_ok else None, None,
                 "model" if heads_ok else None, None)
    else:
        seq_ok = tp > 1 and k_new.shape[1] % tp == 0
        spec = P(dp if b_ok else None, "model" if seq_ok else None,
                 None, None)
    return jax.lax.with_sharding_constraint(k_new, NamedSharding(_MESH, spec))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Serving rules (mesh-sharded ServeEngine — DESIGN.md §5)
# ---------------------------------------------------------------------------
# The serving mesh maps the paper's chip→bank→subarray hierarchy:
#
#   chips     -> "data"  axis: continuous-batching slots (the decode-state
#                grid's batch dim and the per-slot ctrl block)
#   banks     -> "model" axis: N-dim column split of every projection weight
#                — for prepacked weights that means the PackedWeight planes
#                (bits, N, K/32), codes and correction col_sums split on N
#   subarrays -> VMEM tiles inside the bit-serial kernels (BlockSpec)
#
# Parameters shard on "model" ONLY. Serving never takes the FSDP rules:
# ZeRO-style parameter sharding would all-gather every weight every decode
# step, which is exactly the data movement the paper's mapping avoids.
# KV-cache heads and recurrent hidden dims ride "model" so the TP-sharded
# projections write decode state without any resharding in the hot loop.

def _serve_param_spec(path, leaf, mesh: Mesh) -> P:
    dicts = [k.key for k in path if hasattr(k, "key")]
    attrs = [k.name for k in path if hasattr(k, "name")]
    name = dicts[-1] if dicts else ""
    if not hasattr(leaf, "ndim"):
        return P()
    # embed stays replicated: its primary op is the token gather, and the
    # tied-head GEMM on a TP-sharded vocab would gather logits anyway.
    rule = None if name == "embed" else _PARAM_RULES.get(name)
    if rule is None:
        return P(*(None,) * leaf.ndim)
    if "ffn" in dicts and name in _MOE_3D:
        # Expert-stacked MoE bank (float (E, d, f) or an expert-vmapped
        # PackedWeight, possibly under a scan-reps axis). Experts = the
        # paper's chips: when E divides the "model" axis, whole experts
        # deal out across it — every field, including the per-expert wq
        # leaves — so each bank's GEMMs are collective-free and only the
        # token dispatch/combine communicates (DESIGN.md §11). When E
        # doesn't divide (grok's 8e on a wider axis), fall through to the
        # padded TP mapping: d_ff splits inside every expert.
        stacked = 1 if (dicts and dicts[0] == "scan") else 0
        field = attrs[0] if attrs else None
        rank = {"codes": 2, "planes": 3, "col_sums": 1, "wq": 0,
                None: 2}.get(field)
        if rank is not None and leaf.ndim == rank + stacked + 1:
            e = leaf.shape[stacked]
            ms = axis_size(mesh, "model")
            if ms > 1 and e % ms == 0:
                return _guard((None,) * stacked + ("model",) + (None,) * rank,
                              leaf.shape, mesh, label=f"serve-param:{name}:ep")
    base = tuple("model" if t == "tp" else None for t in rule)
    if attrs:
        # Inside a PackedWeight: map the logical (K, N) rule onto the packed
        # representation. attrs[0] == "wq" means QuantParams scale/qmin
        # (per-tensor scalars) and conv extras stay replicated.
        k_ax, n_ax = (base + (None, None))[:2]
        field = attrs[0]
        if field == "codes":
            spec = (k_ax, n_ax)
        elif field == "planes":
            spec = (None, n_ax, k_ax)          # (bits, N, K//32)
        elif field == "col_sums":
            spec = (n_ax,)
        else:
            return P(*(None,) * leaf.ndim)
    else:
        spec = base
    spec = tuple(spec)[:leaf.ndim]
    if leaf.ndim > len(spec):                  # scan-stacked leading reps axis
        spec = (None,) * (leaf.ndim - len(spec)) + spec
    return _guard(spec, leaf.shape, mesh, label=f"serve-param:{name}")


def serve_param_shardings(params_tree, mesh: Mesh):
    """Serving shardings for a (possibly prepacked) param tree: TP on
    "model" only, PackedWeight planes/col_sums split on their N dim."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, _serve_param_spec(p, l, mesh)),
        params_tree)


def _serve_state_spec(path, leaf, mesh: Mesh) -> P:
    names = [k.key for k in path if hasattr(k, "key")]
    name = names[-1] if names else ""
    stacked = bool(names) and names[0] == "scan"
    if name in ("k", "v"):                     # (B, S, H, hd): heads on TP —
        spec = ("data", None, "model", None)   # aligned with the wk/wv column
    elif name in ("k_scale", "v_scale"):       # split, so the per-token KV
        spec = ("data", None, "model")         # write never reshards
    elif name == "wkv":                        # (B, H, D, D)
        spec = ("data", "model", None, None)
    elif name in ("tm_shift", "cm_shift", "h"):
        spec = ("data", "model")
    elif name == "conv":                       # (B, K-1, W)
        spec = ("data", None, "model")
    elif name == "length":
        spec = ("data",)
    else:
        spec = (None,) * leaf.ndim
    if stacked:
        spec = (None,) + tuple(spec)
    return _guard(tuple(spec), leaf.shape, mesh, label=f"serve-state:{name}")


def serve_state_shardings(state_tree, mesh: Mesh):
    """Decode-state grid shardings: batch slots (the paper's chips) on
    "data", KV heads / recurrent hidden dims on "model"."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, _serve_state_spec(p, l, mesh)),
        state_tree)


def serve_ctrl_shardings(ctrl_tree, mesh: Mesh):
    """Per-slot ctrl block: (max_batch,) vectors on "data"; the engine PRNG
    key (and anything non-slot-shaped) replicated."""
    def spec(path, leaf):
        name = path[-1].key if path and hasattr(path[-1], "key") else ""
        if name == "key" or leaf.ndim != 1:
            return P(*(None,) * leaf.ndim)
        return _guard(("data",), leaf.shape, mesh, label=f"serve-ctrl:{name}")
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, spec(p, l)), ctrl_tree)


# ---------------------------------------------------------------------------
# CNN serving rules (mesh-sharded VisionEngine — DESIGN.md §6)
# ---------------------------------------------------------------------------
# Same chip→bank mapping as the LM rules, applied to the conv stack:
#
#   chips     -> "data"  axis: the micro-batch bucket (image batch dim)
#   banks     -> "model" axis: output channels O of every conv / N of every
#                FC — for prepacked weights the PackedConvWeight.mat planes,
#                codes, col_sums AND the fused per-kernel-row planes all
#                split on their O dim, so the fused kernel's weight slab and
#                the materialized path's column split agree
#
# Per-channel BN/bias vectors ride "model" with the conv output, so the
# affine+ReLU epilogue is shard-local. The next conv contracts over the
# O-sharded channels: the partial-sum all-reduce is the inherent TP
# collective (the paper's cross-bank accumulation) — nothing weight- or
# activation-map-sized ever gathers in steady state.

def constrain_cnn_conv_input(x):
    """Pin a conv input (B, H, W, C) to batch-on-"data", channels
    replicated, under the CNN serving layout (identity otherwise).

    Between two bank-split convs the activation must redistribute (the
    previous layer's O shards are the next layer's contraction channels) —
    the paper pays the same movement in its *transfer* phase. Constraining
    the INPUT map forces GSPMD to move the (B, H, W, C) activation, never
    the KH*KW-times-larger patch matrix it otherwise gathers after im2col
    (the reshape cannot carry a minor-dim channel sharding, so the whole
    patch matrix replicates in one gather)."""
    if _MESH is None or not _CNN_SERVE_LAYOUT or x.ndim != 4:
        return x
    dp = dp_axes(_MESH)
    b_ok = dp and x.shape[0] % axis_size(_MESH, *dp) == 0
    spec = P(dp if b_ok else None, None, None, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def constrain_cnn_conv_output(y):
    """Pin a conv output (B, OH, OW, O) to the bank split — O on "model" —
    under the CNN serving layout (identity otherwise). With the input map
    replicated per shard, each bank then computes exactly its own output
    channels from the resident weight planes: the matmul itself needs no
    collective, and the per-channel BN/ReLU epilogue stays shard-local."""
    if _MESH is None or not _CNN_SERVE_LAYOUT or y.ndim != 4:
        return y
    dp = dp_axes(_MESH)
    b_ok = dp and y.shape[0] % axis_size(_MESH, *dp) == 0
    tp = axis_size(_MESH, "model")
    o_ok = tp > 1 and y.shape[-1] % tp == 0
    spec = P(dp if b_ok else None, None, None, "model" if o_ok else None)
    return jax.lax.with_sharding_constraint(y, NamedSharding(_MESH, spec))


def _serve_cnn_param_spec(path, leaf, mesh: Mesh) -> P:
    attrs = [k.name for k in path if hasattr(k, "name")]
    dicts = [k.key for k in path if hasattr(k, "key")]
    name = dicts[-1] if dicts else ""
    if not hasattr(leaf, "ndim"):
        return P()
    if attrs:
        # Inside a PackedWeight / PackedConvWeight: split every
        # representation of the weight on its output-channel dim.
        field = attrs[-1]
        if field == "codes":            # (K, O)
            spec = (None, "model")
        elif field == "planes":         # (bits, O, KW)
            spec = (None, "model", None)
        elif field == "col_sums":       # (O,)
            spec = ("model",)
        elif field == "fused_planes":   # (KH, bits, O, KW, CW)
            spec = (None, None, "model", None, None)
        else:                           # QuantParams scale/qmin
            return P(*(None,) * leaf.ndim)
    elif name in ("b", "gamma", "beta", "mean", "var") and leaf.ndim == 1:
        spec = ("model",)               # per-output-channel epilogue vectors
    else:
        return P(*(None,) * leaf.ndim)
    return _guard(tuple(spec), leaf.shape, mesh, label=f"serve-cnn:{name}")


def serve_cnn_param_shardings(params_tree, mesh: Mesh, quantized: bool = True):
    """CNN serving shardings (DESIGN.md §6).

    ``quantized=True`` (a prepacked tree): every representation of every
    conv/fc weight — ``PackedConvWeight.mat`` codes/planes/col_sums, the
    ``fused_planes``, FC ``PackedWeight`` leaves — splits on its
    output-channel dim (the paper's banks on "model"), along with the
    per-channel BN/bias epilogue vectors.

    ``quantized=False`` (float masters): everything replicates and serving
    is data-parallel only. The bank split is a property of the *bit-serial*
    deployment: its integer partials stay exact under any partitioning,
    while splitting a float contraction would reorder partial sums and
    break the engine's bit-identity contract with ``model.apply``."""
    if not quantized:
        return jax.tree.map(lambda l: NamedSharding(mesh, P()), params_tree)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, _serve_cnn_param_spec(p, l, mesh)),
        params_tree)


def serve_cnn_batch_sharding(mesh: Mesh, batch: int, rank: int = 4):
    """Image micro-batch (B, H, W, C): batch on "data" (the paper's chips)
    when the bucket divides the axis, else replicated."""
    spec = [None] * rank
    if "data" in mesh.axis_names and axis_size(mesh, "data") > 1 \
            and batch % axis_size(mesh, "data") == 0:
        spec[0] = "data"
    return NamedSharding(mesh, P(*spec))


def serve_cnn_logits_sharding(mesh: Mesh, batch: int):
    """Engine forward output (B, classes): batch stays on "data"; the class
    dim is host-bound (top-1 / completion assembly) and small, so it is
    never worth sharding."""
    return serve_cnn_batch_sharding(mesh, batch, rank=2)


def serve_stream_sharding(mesh: Mesh, n_slots: int, rank: int = 2,
                          slot_dim: int = 1):
    """Sharding for the (steps, slots) token/done streams a decode dispatch
    emits: slots on "data" so the hot loop ends with no gather — the host
    assembles the (tiny) stream after the dispatch returns."""
    spec = [None] * rank
    if "data" in mesh.axis_names and axis_size(mesh, "data") > 1 \
            and n_slots % axis_size(mesh, "data") == 0:
        spec[slot_dim] = "data"
    return NamedSharding(mesh, P(*spec))
