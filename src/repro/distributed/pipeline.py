"""Pipeline parallelism: GPipe-style microbatch schedule over a "stage"
mesh axis, built on shard_map + collective_permute.

Scope: homogeneous stages (each stage applies the same ``stage_fn`` with
its own slice of stacked parameters) — which matches this framework's
scan-over-repeating-units models exactly: a stage is a contiguous run of
unit repetitions, so any arch whose depth factors into n_stages pipelines
without new code. The schedule is the classic (M microbatches, S stages,
M + S − 1 ticks) fill-drain pipeline; bubble fraction (S−1)/(M+S−1).

At production scale the stage axis maps onto the `pod` axis (cross-pod
point-to-point permutes ride DCN, the cheapest pattern for that fabric);
on this container it is exercised on a 4-device CPU mesh
(tests/test_pipeline.py) and the schedule's output is verified against the
sequential application of all stages.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax.lax.pvary landed after 0.4.x; the shard_map version split lives in
# collectives.shard_map_compat. The replication checker is disabled here:
# the ppermute/psum pattern below is device-varying by design.
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def _shard_map(f, mesh, in_specs, out_specs):
    from .collectives import shard_map_compat

    return shard_map_compat(f, mesh, in_specs=in_specs, out_specs=out_specs,
                            check_rep=False)


def pipeline_forward(stage_params, x_microbatches, stage_fn, mesh,
                     stage_axis: str = "stage"):
    """Run the fill-drain pipeline.

    stage_params: pytree, leaves (S, ...) — stage-major stacked params.
    x_microbatches: (M, mb, ...) — microbatched input.
    stage_fn(params_slice, x) -> y with y.shape == x.shape (residual stages).
    Returns (M, mb, ...) outputs, equal to applying all S stages in order.
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x_microbatches.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def per_stage(params_local, xs):
        # params_local leaves: (1, ...) local stage slice; xs: (M, mb, ...)
        params_local = jax.tree.map(lambda l: l[0], params_local)
        stage_id = jax.lax.axis_index(stage_axis)
        mb_shape = xs.shape[1:]
        # carries become device-varying inside the loop (ppermute/axis_index)
        # — mark the initial values as varying for shard_map's vma typing.
        outputs = _pvary(jnp.zeros_like(xs), (stage_axis,))
        carry_in = _pvary(jnp.zeros(mb_shape, xs.dtype), (stage_axis,))

        def tick(t, state):
            outputs, carry_in = state
            # Stage 0 ingests microbatch t (while available); others take
            # the permuted output of their predecessor.
            feed = jnp.where(t < n_micro,
                             xs[jnp.minimum(t, n_micro - 1)],
                             jnp.zeros(mb_shape, xs.dtype))
            x_in = jnp.where(stage_id == 0, feed, carry_in)
            y = stage_fn(params_local, x_in)
            # Last stage emits microbatch t-(S-1) once the pipe is full.
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (stage_id == n_stages - 1) & (t >= n_stages - 1)
            outputs = jnp.where(
                emit,
                jax.lax.dynamic_update_index_in_dim(outputs, y, out_idx, 0),
                outputs)
            carry_in = jax.lax.ppermute(y, stage_axis, perm)
            return outputs, carry_in

        outputs, _ = jax.lax.fori_loop(0, ticks, tick, (outputs, carry_in))
        # Only the last stage holds real outputs; psum-mask to share them.
        outputs = jnp.where(stage_id == n_stages - 1, outputs, 0.0)
        return jax.lax.psum(outputs, stage_axis)

    return _shard_map(
        per_stage, mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
    )(stage_params, x_microbatches)


def split_stages(stacked_params, n_stages: int):
    """(R, ...) scan-stacked params -> (S, R/S, ...) stage-major view."""
    def re(l):
        r = l.shape[0]
        if r % n_stages:
            raise ValueError(
                f"cannot pipeline: {r} scanned repetition(s) do not factor "
                f"into {n_stages} equal stages (reps % n_stages must be 0)")
        return l.reshape(n_stages, r // n_stages, *l.shape[1:])
    return jax.tree.map(re, stacked_params)


def pipeline_decode_step(params, cfg, tokens, state, *, mesh,
                         n_stages: int, n_microbatch: int | None = None,
                         stage_axis: str = "stage", image_embeds=None,
                         return_stats: bool = False):
    """One decode step with the scanned repetitions pipelined over stages.

    Drop-in for ``models.lm.decode_step`` (same signature prefix, same
    return contract) on a 1-D ``(stage,)`` mesh: the scan-stacked unit
    repetitions split into ``n_stages`` contiguous stages (``split_stages``
    semantics), the batch splits into ``n_microbatch`` microbatches
    (default ``n_stages``), and the classic fill-drain schedule streams
    microbatches through the stages with one ``collective_permute`` hop per
    tick. Each stage holds only its own layers' parameters and KV/recurrent
    state slice — the model-parallel memory story — and updates the decode
    state in place per microbatch column, masked on pipeline-bubble ticks
    so invalid ticks write nothing. Embedding, remainder layers, final norm
    and the LM head run replicated outside the pipe (they are depth-1).

    Bit-parity: for per-example-independent models (dense float) the
    result is bitwise equal to sequential ``decode_step`` — microbatching
    only slices the batch axis. MoE capacity and PIM activation calibration
    are batch-shape-dependent by definition (per-group capacity, per-tensor
    calibration), so those paths are numerically equivalent per-microbatch
    semantics, not bitwise reproductions of the full-batch step.
    """
    from repro.models.lm.model import (_zero_aux, apply_block, apply_norm,
                                       embed_inputs, layer_plan, lm_head)

    unit, reps, rest = layer_plan(cfg)
    if reps % n_stages:
        raise ValueError(
            f"cannot pipeline: {reps} scanned repetition(s) do not factor "
            f"into {n_stages} equal stages (reps % n_stages must be 0)")
    n_micro = n_microbatch or n_stages
    b = tokens.shape[0]
    if b % n_micro:
        raise ValueError(
            f"cannot pipeline: batch {b} does not split into "
            f"{n_micro} equal microbatches")
    mb = b // n_micro
    ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    x = embed_inputs(params, cfg, tokens)                    # (B, 1, d)
    idx = jnp.broadcast_to(state["length"], (b,)).astype(jnp.int32)
    q_pos = idx[:, None]
    d = x.shape[-1]

    # P(stage) in_specs split the leading (R,) reps axis into S contiguous
    # chunks of R/S — exactly ``split_stages``'s stage-major factoring, with
    # no host-side reshape of the (donated) decode state.
    xm = x.reshape(n_micro, mb, 1, d)
    qm = q_pos.reshape(n_micro, mb, 1)
    im = idx.reshape(n_micro, mb)

    def per_stage(sp_l, ss_l, xm, qm, im):
        # sp_l leaves (R/S, ...), ss_l leaves (R/S, B, ...): this stage's
        # contiguous run of unit repetitions and their decode state.
        stage_id = jax.lax.axis_index(stage_axis)
        outputs = _pvary(jnp.zeros_like(xm), (stage_axis,))
        carry = _pvary(jnp.zeros((mb, 1, d), x.dtype), (stage_axis,))
        aux0 = jax.tree.map(lambda v: _pvary(v, (stage_axis,)), _zero_aux())

        def unit_scan(x_in, ss_slice, qp, ci):
            def unit_fn(xc, per_rep):
                p_list, s_list = per_rep
                new_states, a = [], _zero_aux()
                for j, kind in enumerate(unit):
                    xc, ns, a1 = apply_block(kind, p_list[j], cfg, xc, qp,
                                             s_list[j], ci, image_embeds)
                    new_states.append(ns)
                    a = jax.tree.map(jnp.add, a, a1)
                return xc, (new_states, a)
            y, (new_s, a_reps) = jax.lax.scan(unit_fn, x_in, (sp_l, ss_slice))
            return y, new_s, jax.tree.map(jnp.sum, a_reps)

        def tick(t, loop):
            outputs, carry, ss_l, aux = loop
            m = t - stage_id
            valid = (m >= 0) & (m < n_micro)
            mc = jnp.clip(m, 0, n_micro - 1)
            x_in = jnp.where(stage_id == 0,
                             jax.lax.dynamic_index_in_dim(xm, mc, 0,
                                                          keepdims=False),
                             carry)
            qp = jax.lax.dynamic_index_in_dim(qm, mc, 0, keepdims=False)
            ci = jax.lax.dynamic_index_in_dim(im, mc, 0, keepdims=False)
            # This stage's state columns for microbatch mc (batch axis 1 on
            # scan-stacked decode-state leaves, by cache construction).
            ss_slice = jax.tree.map(
                lambda l: jax.lax.dynamic_slice_in_dim(l, mc * mb, mb, 1),
                ss_l)
            y, new_s, a = unit_scan(x_in, ss_slice, qp, ci)
            # Bubble ticks (fill/drain) must not touch state or outputs.
            ss_l = jax.tree.map(
                lambda big, sm: jnp.where(
                    valid,
                    jax.lax.dynamic_update_slice_in_dim(
                        big, sm.astype(big.dtype), mc * mb, 1),
                    big),
                ss_l, new_s)
            emit = valid & (stage_id == n_stages - 1)
            outputs = jnp.where(
                emit,
                jax.lax.dynamic_update_index_in_dim(outputs, y, mc, 0),
                outputs)
            aux = jax.tree.map(
                lambda acc, v: acc + jnp.where(valid, v, 0.0), aux, a)
            carry = jax.lax.ppermute(y, stage_axis, perm)
            return outputs, carry, ss_l, aux

        outputs, _, ss_l, aux = jax.lax.fori_loop(
            0, ticks, tick, (outputs, carry, ss_l, aux0))
        # Only the last stage holds real outputs; every stage holds the aux
        # of its own layers — psum shares/accumulates them across the pipe.
        outputs = jnp.where(stage_id == n_stages - 1, outputs, 0.0)
        outputs = jax.lax.psum(outputs, stage_axis)
        aux = jax.tree.map(lambda v: jax.lax.psum(v, stage_axis), aux)
        return outputs, ss_l, aux

    outputs, new_scan, aux = _shard_map(
        per_stage, mesh,
        in_specs=(P(stage_axis), P(stage_axis), P(), P(), P()),
        out_specs=(P(), P(stage_axis), P()),
    )(params["scan"], state["scan"], xm, qm, im)

    x = outputs.reshape(b, 1, d)

    new_rest = []
    for i, kind in enumerate(rest):
        x, ns, a = apply_block(kind, params["rest"][i], cfg, x, q_pos,
                               state["rest"][i], idx, image_embeds)
        new_rest.append(ns)
        aux = jax.tree.map(jnp.add, aux, a)

    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params, cfg, x)
    new_state = dict(state, scan=new_scan, rest=new_rest,
                     length=state["length"] + 1)
    if return_stats:
        stats = {"moe_drop_frac": aux["drop"]
                 / jnp.maximum(aux["layers"], 1.0)}
        return logits, new_state, stats
    return logits, new_state


def make_unit_stage_fn(cfg, unit, q_pos):
    """Stage body for scanned-unit LM models: applies R/S unit reps."""
    from repro.models.lm.model import apply_block

    def stage_fn(params_slice, x):
        def unit_fn(x, p_list):
            for j, kind in enumerate(unit):
                x, _, _ = apply_block(kind, p_list[j], cfg, x, q_pos)
            return x, None
        x, _ = jax.lax.scan(unit_fn, x, params_slice)
        return x

    return stage_fn
