"""Pipeline parallelism: GPipe-style microbatch schedule over a "stage"
mesh axis, built on shard_map + collective_permute.

Scope: homogeneous stages (each stage applies the same ``stage_fn`` with
its own slice of stacked parameters) — which matches this framework's
scan-over-repeating-units models exactly: a stage is a contiguous run of
unit repetitions, so any arch whose depth factors into n_stages pipelines
without new code. The schedule is the classic (M microbatches, S stages,
M + S − 1 ticks) fill-drain pipeline; bubble fraction (S−1)/(M+S−1).

At production scale the stage axis maps onto the `pod` axis (cross-pod
point-to-point permutes ride DCN, the cheapest pattern for that fabric);
on this container it is exercised on a 4-device CPU mesh
(tests/test_pipeline.py) and the schedule's output is verified against the
sequential application of all stages.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax.lax.pvary landed after 0.4.x; the shard_map version split lives in
# collectives.shard_map_compat. The replication checker is disabled here:
# the ppermute/psum pattern below is device-varying by design.
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def _shard_map(f, mesh, in_specs, out_specs):
    from .collectives import shard_map_compat

    return shard_map_compat(f, mesh, in_specs=in_specs, out_specs=out_specs,
                            check_rep=False)


def pipeline_forward(stage_params, x_microbatches, stage_fn, mesh,
                     stage_axis: str = "stage"):
    """Run the fill-drain pipeline.

    stage_params: pytree, leaves (S, ...) — stage-major stacked params.
    x_microbatches: (M, mb, ...) — microbatched input.
    stage_fn(params_slice, x) -> y with y.shape == x.shape (residual stages).
    Returns (M, mb, ...) outputs, equal to applying all S stages in order.
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x_microbatches.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def per_stage(params_local, xs):
        # params_local leaves: (1, ...) local stage slice; xs: (M, mb, ...)
        params_local = jax.tree.map(lambda l: l[0], params_local)
        stage_id = jax.lax.axis_index(stage_axis)
        mb_shape = xs.shape[1:]
        # carries become device-varying inside the loop (ppermute/axis_index)
        # — mark the initial values as varying for shard_map's vma typing.
        outputs = _pvary(jnp.zeros_like(xs), (stage_axis,))
        carry_in = _pvary(jnp.zeros(mb_shape, xs.dtype), (stage_axis,))

        def tick(t, state):
            outputs, carry_in = state
            # Stage 0 ingests microbatch t (while available); others take
            # the permuted output of their predecessor.
            feed = jnp.where(t < n_micro,
                             xs[jnp.minimum(t, n_micro - 1)],
                             jnp.zeros(mb_shape, xs.dtype))
            x_in = jnp.where(stage_id == 0, feed, carry_in)
            y = stage_fn(params_local, x_in)
            # Last stage emits microbatch t-(S-1) once the pipe is full.
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (stage_id == n_stages - 1) & (t >= n_stages - 1)
            outputs = jnp.where(
                emit,
                jax.lax.dynamic_update_index_in_dim(outputs, y, out_idx, 0),
                outputs)
            carry_in = jax.lax.ppermute(y, stage_axis, perm)
            return outputs, carry_in

        outputs, _ = jax.lax.fori_loop(0, ticks, tick, (outputs, carry_in))
        # Only the last stage holds real outputs; psum-mask to share them.
        outputs = jnp.where(stage_id == n_stages - 1, outputs, 0.0)
        return jax.lax.psum(outputs, stage_axis)

    return _shard_map(
        per_stage, mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
    )(stage_params, x_microbatches)


def split_stages(stacked_params, n_stages: int):
    """(R, ...) scan-stacked params -> (S, R/S, ...) stage-major view."""
    def re(l):
        r = l.shape[0]
        assert r % n_stages == 0, f"{r} reps not divisible by {n_stages} stages"
        return l.reshape(n_stages, r // n_stages, *l.shape[1:])
    return jax.tree.map(re, stacked_params)


def make_unit_stage_fn(cfg, unit, q_pos):
    """Stage body for scanned-unit LM models: applies R/S unit reps."""
    from repro.models.lm.model import apply_block

    def stage_fn(params_slice, x):
        def unit_fn(x, p_list):
            for j, kind in enumerate(unit):
                x, _, _ = apply_block(kind, p_list[j], cfg, x, q_pos)
            return x, None
        x, _ = jax.lax.scan(unit_fn, x, params_slice)
        return x

    return stage_fn
