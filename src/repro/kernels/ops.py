"""Public jit'd wrappers for the Pallas kernels.

On a TPU backend the kernels run compiled; on CPU (this container) they run
in ``interpret=True`` mode, which executes the kernel body in Python with
identical semantics — that is how correctness is validated here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitslice
from repro.core.mapping import plan_matmul

from . import bitplane_pack as _pack
from . import bitserial_matmul as _bsm


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def pack_planes(q: jax.Array, bits: int, interpret: bool | None = None) -> jax.Array:
    """Integer codes (M, K) -> packed planes (bits, M, ceil32(K)/32) uint32."""
    if interpret is None:
        interpret = _interpret_default()
    m, k = q.shape
    kp = bitslice.pad_to_lanes(k)
    if kp != k:
        q = jnp.pad(q, ((0, 0), (0, kp - k)))
    kw = kp // 32
    # Block shapes must divide; fall back to whole-array blocks when small.
    bm = m if m < 256 or m % 256 else 256
    bkw = kw if kw < 128 or kw % 128 else 128
    return _pack.bitplane_pack(q, bits=bits, bm=bm, bkw=bkw, interpret=interpret)


def bitserial_matmul(
    qa: jax.Array,  # (M, K) int codes
    qw: jax.Array,  # (K, N) int codes
    *,
    a_bits: int,
    w_bits: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Eq. 1 bit-serial integer matmul via the Pallas kernels -> (M, N) i32."""
    if interpret is None:
        interpret = _interpret_default()
    m, k = qa.shape
    _, n = qw.shape
    pa = pack_planes(qa, a_bits, interpret)
    pw = pack_planes(qw.T, w_bits, interpret)
    kw = pa.shape[-1]
    plan = plan_matmul(m, k, n, a_bits, w_bits)
    bm = _divisor_block(m, plan.bm)
    bn = _divisor_block(n, plan.bn)
    bkw = _divisor_block(kw, plan.bk_words)
    return _bsm.bitserial_matmul_packed(
        pa, pw, a_bits=a_bits, w_bits=w_bits, bm=bm, bn=bn, bkw=bkw,
        interpret=interpret,
    )


def _divisor_block(dim: int, want: int) -> int:
    """Largest block <= want that divides dim (Pallas grids need exact tiling)."""
    b = min(want, dim)
    while dim % b:
        b -= 1
    return b
