"""Public jit'd wrappers for the Pallas kernels.

On a TPU backend the kernels run compiled; on CPU (this container) they run
in ``interpret=True`` mode, which executes the kernel body in Python with
identical semantics — that is how correctness is validated here.

``bitserial_matmul`` is ONE kernel launch when the weight planes arrive
prepacked (``pw=``, from :class:`repro.core.packed.PackedWeight`): the
activation codes are bit-sliced and lane-packed inside the matmul kernel's
K-tile loop, so no packed plane ever round-trips through HBM. With raw
weight codes it is two launches (weight pack + fused matmul) — still down
from the historical three (pack A, pack W, matmul).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core import bitslice
from repro.core.mapping import plan_matmul

from . import bitplane_pack as _pack
from . import bitserial_matmul as _bsm
from . import conv2d_fused as _conv


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def pack_planes(q: jax.Array, bits: int, interpret: bool | None = None) -> jax.Array:
    """Integer codes (M, K) -> packed planes (bits, M, ceil32(K)/32) uint32."""
    if interpret is None:
        interpret = _interpret_default()
    m, k = q.shape
    kp = bitslice.pad_to_lanes(k)
    if kp != k:
        q = jnp.pad(q, ((0, 0), (0, kp - k)))
    kw = kp // 32
    # Block shapes must divide; fall back to whole-array blocks when small.
    bm = m if m < 256 or m % 256 else 256
    bkw = kw if kw < 128 or kw % 128 else 128
    return _pack.bitplane_pack(q, bits=bits, bm=bm, bkw=bkw, interpret=interpret)


def matmul_tiles(m: int, n: int, kw: int, a_bits: int, w_bits: int,
                 bm: int | None = None, bn: int | None = None,
                 bkw: int | None = None) -> tuple:
    """Legal (bm, bn, bkw) blocks for an (M, N, KW-words) bit-serial matmul.

    ``bm``/``bn``/``bkw`` are *requests* — autotuner overrides
    (:class:`repro.core.packed.TuneDecision`) or caller choices; ``None``
    falls back to the :func:`plan_matmul` planner. Every request is
    legalized to the largest divisor of its dim, so the kernel's
    ``_check_blocks`` precondition holds by construction for any request.
    """
    plan = plan_matmul(m, kw * 32, n, a_bits, w_bits)
    return (_divisor_block(m, bm or plan.bm),
            _divisor_block(n, bn or plan.bn),
            _divisor_block(kw, bkw or plan.bk_words))


def bitserial_matmul(
    qa: jax.Array,            # (M, K) int codes
    qw: jax.Array | None = None,  # (K, N) int codes (omit when pw given)
    *,
    a_bits: int,
    w_bits: int,
    pw: jax.Array | None = None,  # (w_bits, N, ceil32(K)/32) prepacked planes
    bm: int | None = None,
    bn: int | None = None,
    bkw: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Eq. 1 bit-serial integer matmul via the Pallas kernels -> (M, N) i32.

    Activation packing is fused into the matmul kernel; pass ``pw`` (the
    prepacked weight planes of a ``PackedWeight``) to make the whole product
    a single ``pallas_call``. ``bm``/``bn``/``bkw`` override the planner's
    tile choices (see :func:`matmul_tiles`); the autotuner threads its
    decisions through here.
    """
    if interpret is None:
        interpret = _interpret_default()
    m, k = qa.shape
    if pw is None:
        if qw is None:
            raise ValueError("need either qw codes or pw prepacked planes")
        pw = pack_planes(qw.T, w_bits, interpret)
    n = pw.shape[1]
    kw = pw.shape[-1]
    if k > kw * 32:
        raise ValueError(
            f"activation K={k} exceeds packed weight K={kw * 32} words*32")
    if kw * 32 != k:
        qa = jnp.pad(qa, ((0, 0), (0, kw * 32 - k)))
    bm, bn, bkw = matmul_tiles(m, n, kw, a_bits, w_bits, bm, bn, bkw)
    return _bsm.bitserial_matmul_fused(
        qa, pw, a_bits=a_bits, w_bits=w_bits, bm=bm, bn=bn, bkw=bkw,
        interpret=interpret,
    )


def conv2d_bitserial(
    qx: jax.Array,   # (N, Hp, Wp, C) int32 activation codes, spatially padded
    pw: jax.Array,   # (KH, w_bits, O, KW, CW) PackedConvWeight.fused_planes
    *,
    a_bits: int,
    stride: int = 1,
    bo: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Implicit-im2col bit-serial conv -> P (N, OH, OW, O) int32.

    Packs the channel axis of the already-padded activation codes and runs
    the fused kernel; the (N*OH*OW, KH*KW*C) patch matrix is never built.
    ``bo`` overrides the kernel's output-channel block (autotuner hook);
    None keeps the lane-width default.
    """
    if interpret is None:
        interpret = _interpret_default()
    n, hp, wp, c = qx.shape
    kh, _, o, kw_sz, cw = pw.shape
    # STT-MRAM read disturb: under an active fault scope each launch senses
    # a freshly disturbed view of the stored planes. Trace-time no-op (and
    # HLO-identical) when the scope is inactive.
    from repro.pim import faults as _faults

    if _faults.read_disturb_active():
        pw = _faults.disturb_fused_planes(pw, (kh, kw_sz, c, o))
    oh = (hp - kh) // stride + 1
    ow = (wp - kw_sz) // stride + 1
    # Channel pack through the Pallas pack kernel: block-tiled in VMEM, so
    # no full-size (a_bits, N, Hp, Wp, C) bit-plane broadcast ever exists —
    # the XLA slice_and_pack would allocate one as large as the im2col
    # matrix itself (see tests/test_fastpath.py jaxpr assertion).
    pa = pack_planes(qx.reshape(n * hp * wp, c), a_bits, interpret)
    if pa.shape[-1] != cw:
        raise ValueError(f"channel words {pa.shape[-1]} != weight words {cw}")
    pa = pa.reshape(a_bits, n * hp, wp, cw)
    kw_conv = {} if bo is None else {"bo": bo}
    return _conv.conv2d_bitserial_fused(
        pa, pw, n=n, hp=hp, oh=oh, ow=ow, stride=stride, interpret=interpret,
        **kw_conv)


def _divisor_block(dim: int, want: int) -> int:
    """Largest block <= want that divides dim (Pallas grids need exact tiling)."""
    b = min(want, dim)
    while dim % b:
        b -= 1
    return b
