"""Pallas kernel: fused implicit-im2col bit-serial convolution.

The materialized conv lowering builds the (N*OH*OW, KH*KW*C) patch matrix in
HBM — a KH*KW-fold blow-up of the activation that the paper's architecture
never pays: NAND-SPIN slides the weight buffer over *resident* input planes
(Fig. 8's row-activation schedule). This kernel reproduces that property on
TPU: the grid's K axis walks the KH kernel-row offsets, and each grid step
streams exactly one padded input row per activation plane from HBM; the KW
offsets are walked *inside* the kernel with strided VMEM slices. No patch
matrix ever exists in any memory space.

Layouts (built by :func:`repro.kernels.ops.conv2d_bitserial`):

  pa  (a_bits, N*Hp, Wp, CW) uint32 — activation codes packed along C
      (CW = ceil(C/32) words); spatial padding applied beforehand with the
      ZERO code (which ANDs to zero popcount — padded taps contribute
      nothing to P), so patches match the materialized path bit-exactly.
  pw  (KH, w_bits, O, KW, CW) uint32 — per-kernel-row weight planes
      (``PackedConvWeight.fused_planes``).
  out (N*OH, OW, O) int32 — P tiles; the (OW, bo) accumulator stays in VMEM
      across the KH grid axis (cross-writing, as in the matmul kernel).

Grid = (N*OH, O//bo, KH) with KH innermost. The activation BlockSpec uses a
size-1 block on the row axis, so the index map addresses the *element* row
(n*Hp + oh*stride + kh) directly — that arithmetic is the whole implicit
im2col.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad_o_blocks(o: int, bo: int) -> tuple[int, int]:
    """Output-channel tiling: pick the block and the zero-padding of O.

    The old fallback shrank ``bo`` until it divided O, which degenerates to
    ``bo = 1`` for prime O (an O-sized grid of tiny kernels). Instead keep
    the requested block and pad O up to the next multiple — zero weight
    planes AND to zero popcounts, so the padded columns cost one wasted tile
    and are sliced off after the call.
    """
    bo = min(bo, o)
    return bo, -o % bo


def _kernel(a_ref, w_ref, o_ref, *, a_bits: int, w_bits: int, kw_sz: int,
            ow: int, stride: int, cw: int, bo: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.zeros((ow, bo), jnp.int32)
    for n in range(a_bits):
        row = a_ref[n, 0]                          # (Wp, CW) one padded row
        for dx in range(kw_sz):                    # implicit im2col: KW walk
            # Output positions ow_i read words [dx + ow_i*stride] of the row.
            asl = jax.lax.slice(row, (dx, 0),
                                (dx + (ow - 1) * stride + 1, cw),
                                (stride, 1))       # (ow, CW)
            for m in range(w_bits):
                wv = w_ref[0, m, :, dx, :]         # (bo, CW)
                cnt = jax.lax.population_count(asl[:, None, :] & wv[None, :, :])
                acc += cnt.sum(-1).astype(jnp.int32) << (n + m)
    o_ref[0] += acc


@functools.partial(jax.jit, static_argnames=(
    "n", "hp", "oh", "ow", "stride", "bo", "interpret"))
def conv2d_bitserial_fused(
    pa: jax.Array,  # (a_bits, N*Hp, Wp, CW) uint32 packed activation planes
    pw: jax.Array,  # (KH, w_bits, O, KW, CW) uint32 packed weight planes
    *,
    n: int,
    hp: int,
    oh: int,
    ow: int,
    stride: int = 1,
    bo: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused bit-serial conv -> P (N, OH, OW, O) int32 (integer part of Eq. 1)."""
    a_bits, rows, wp, cw = pa.shape
    kh, w_bits, o, kw_sz, _ = pw.shape
    if rows != n * hp:
        raise ValueError(f"pa rows {rows} != n*hp {n * hp}")
    if wp < (ow - 1) * stride + kw_sz:
        raise ValueError(f"padded width {wp} too small for ow={ow}")
    bo, o_pad = _pad_o_blocks(o, bo)
    if o_pad:
        pw = jnp.pad(pw, ((0, 0), (0, 0), (0, o_pad), (0, 0), (0, 0)))
    op = o + o_pad

    grid = (n * oh, op // bo, kh)
    kern = functools.partial(_kernel, a_bits=a_bits, w_bits=w_bits,
                             kw_sz=kw_sz, ow=ow, stride=stride, cw=cw, bo=bo)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            # Element-addressed row (block size 1 on the row axis):
            # row = n*Hp + oh*stride + kh — the implicit im2col index.
            pl.BlockSpec(
                (a_bits, 1, wp, cw),
                lambda i, j, k: (0, (i // oh) * hp + (i % oh) * stride + k, 0, 0),
            ),
            pl.BlockSpec((1, w_bits, bo, kw_sz, cw),
                         lambda i, j, k: (k, 0, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ow, bo), lambda i, j, k: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n * oh, ow, op), jnp.int32),
        interpret=interpret,
    )(pa, pw)
    if o_pad:
        out = out[..., :o]
    return out.reshape(n, oh, ow, o)
