"""Pallas TPU kernels for the paper's Eq. 1 bit-serial matmul.

Computes ``P[b, o] = sum_{n,m} 2^(n+m) * popcount(pa[n, b, :] & pw[m, o, :])``
over packed uint32 bit-planes — the NAND-SPIN subarray dataflow mapped onto
the TPU memory hierarchy:

  HBM             packed activation planes + packed weight planes
  VMEM (BlockSpec)  one (bm x bkw) activation tile per plane, one (bn x bkw)
                    weight tile per plane  (== the paper's weight buffer)
  VREG/VPU        lane-wise AND + population_count  (== sense-amp AND + column
                    bit-counter)
  VMEM accumulator  output tile revisited across the K grid axis (== the
                    paper's cross-written partial sums staying in-mat)

Grid = (m_tiles, n_tiles, k_tiles) with K innermost, so the int32 output
block stays resident in VMEM while partial popcounts accumulate — partial
sums never round-trip to HBM, which is exactly the property the paper's
cross-writing scheme buys on NAND-SPIN.

Two entry points:

``bitserial_matmul_packed``  both operands pre-packed (a_bits/w_bits, ·, KW)
                             uint32 planes.
``bitserial_matmul_fused``   activations arrive as raw integer *codes*; the
                             kernel bit-slices and lane-packs each K tile in
                             VMEM before the AND+popcount loop, so
                             quantize->pack->popcount is ONE ``pallas_call``
                             and the packed activation planes never
                             round-trip through HBM. Weight planes arrive
                             prepacked (see ``repro.core.packed`` — the
                             paper's program-subarrays-once step).

The (bm, chunk, bkw) broadcast intermediate is tiled by an inner fori_loop
over output-column chunks of 128 lanes to bound VREG/VMEM pressure
(`_OC` below); tiles whose ``bn`` is not a multiple of 128 fall back to an
unchunked accumulation (previously they silently computed only the first
``bn // 128`` lane groups — see tests/test_kernels.py regression). The MXU
is idle in these kernels by design — Eq. 1 is a pure VPU bit-op pipeline.
See ``mxu_plane`` in :mod:`repro.core.bitserial` for the systolic
alternative, and DESIGN.md §2 for the trade-off experiment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-column chunk for the inner loop: one lane group.
_OC = 128


def _accumulate(planes, w_ref, o_ref, *, a_bits: int, w_bits: int, bm: int,
                bn: int, bkw: int):
    """Shared Eq. 1 accumulation: planes[n] is the (bm, bkw) uint32 plane."""
    if bn % _OC:
        # Narrow / non-lane-multiple outputs: no column chunking.
        acc = jnp.zeros((bm, bn), jnp.int32)
        for n in range(a_bits):
            a = planes[n]
            for m in range(w_bits):
                cnt = jax.lax.population_count(a[:, None, :] & w_ref[m][None, :, :])
                acc += cnt.sum(-1).astype(jnp.int32) << (n + m)
    else:
        def oc_body(c, acc):
            # acc: (bm, bn) int32. Process output columns [c*_OC, (c+1)*_OC).
            partial = jnp.zeros((bm, _OC), jnp.int32)
            for n in range(a_bits):          # static unroll: plane pairs
                a = planes[n]                # (bm, bkw) uint32
                for m in range(w_bits):
                    w = jax.lax.dynamic_slice(w_ref[m], (c * _OC, 0), (_OC, bkw))
                    # sense-amp AND + per-column bitcount, 32 cells per lane
                    cnt = jax.lax.population_count(a[:, None, :] & w[None, :, :])
                    partial += cnt.sum(-1).astype(jnp.int32) << (n + m)
            return jax.lax.dynamic_update_slice(acc, partial, (0, c * _OC))

        acc = jax.lax.fori_loop(0, bn // _OC, oc_body,
                                jnp.zeros((bm, bn), jnp.int32))
    o_ref[...] += acc


def _kernel(a_ref, w_ref, o_ref, *, a_bits: int, w_bits: int, bm: int, bn: int,
            bkw: int):
    # Zero the accumulator tile on the first K step (grid axis 2 innermost).
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    planes = [a_ref[n] for n in range(a_bits)]
    _accumulate(planes, w_ref, o_ref, a_bits=a_bits, w_bits=w_bits, bm=bm,
                bn=bn, bkw=bkw)


def _fused_kernel(qa_ref, w_ref, o_ref, *, a_bits: int, w_bits: int, bm: int,
                  bn: int, bkw: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Bit-slice + lane-pack the activation K tile in VMEM: the packed planes
    # are kernel-local, never written to HBM (vs. the 3-launch pipeline).
    q = qa_ref[...].astype(jnp.uint32).reshape(bm, bkw, 32)
    lane_w = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, None, :]
    planes = [(((q >> jnp.uint32(n)) & jnp.uint32(1)) * lane_w).sum(
        -1, dtype=jnp.uint32) for n in range(a_bits)]
    _accumulate(planes, w_ref, o_ref, a_bits=a_bits, w_bits=w_bits, bm=bm,
                bn=bn, bkw=bkw)


def _check_blocks(m, n, kw, bm, bn, bkw):
    if m % bm or n % bn or kw % bkw:
        raise ValueError(
            f"shape ({m},{n},{kw}) not divisible by blocks ({bm},{bn},{bkw})")


@functools.partial(
    jax.jit, static_argnames=("a_bits", "w_bits", "bm", "bn", "bkw", "interpret")
)
def bitserial_matmul_packed(
    pa: jax.Array,  # (a_bits, M, KW) uint32 packed activation planes
    pw: jax.Array,  # (w_bits, N, KW) uint32 packed weight planes
    *,
    a_bits: int,
    w_bits: int,
    bm: int = 128,
    bn: int = 128,
    bkw: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Packed-plane bit-serial matmul -> (M, N) int32."""
    _, m, kw = pa.shape
    _, n, _ = pw.shape
    bm = min(bm, m)
    bn = min(bn, n)
    bkw = min(bkw, kw)
    _check_blocks(m, n, kw, bm, bn, bkw)

    grid = (m // bm, n // bn, kw // bkw)
    kern = functools.partial(
        _kernel, a_bits=a_bits, w_bits=w_bits, bm=bm, bn=bn, bkw=bkw
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((a_bits, bm, bkw), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((w_bits, bn, bkw), lambda i, j, k: (0, j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(pa, pw)


@functools.partial(
    jax.jit, static_argnames=("a_bits", "w_bits", "bm", "bn", "bkw", "interpret")
)
def bitserial_matmul_fused(
    qa: jax.Array,  # (M, K) int32 activation codes, K % 32 == 0
    pw: jax.Array,  # (w_bits, N, K//32) uint32 prepacked weight planes
    *,
    a_bits: int,
    w_bits: int,
    bm: int = 128,
    bn: int = 128,
    bkw: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Fused pack+matmul: activation codes in, (M, N) int32 out, one launch."""
    m, k = qa.shape
    _, n, kw = pw.shape
    if k != kw * 32:
        raise ValueError(f"K={k} does not match packed weight KW={kw}")
    bm = min(bm, m)
    bn = min(bn, n)
    bkw = min(bkw, kw)
    _check_blocks(m, n, kw, bm, bn, bkw)

    grid = (m // bm, n // bn, kw // bkw)
    kern = functools.partial(
        _fused_kernel, a_bits=a_bits, w_bits=w_bits, bm=bm, bn=bn, bkw=bkw
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bkw * 32), lambda i, j, k: (i, k)),
            pl.BlockSpec((w_bits, bn, bkw), lambda i, j, k: (0, j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(qa, pw)


def bitserial_matmul_sharded(
    qa: jax.Array,  # (M, K) int32 activation codes, K = KW*32
    pw: jax.Array,  # (w_bits, N, KW) uint32 prepacked weight planes
    *,
    a_bits: int,
    w_bits: int,
    mesh,
    axis: str = "model",
    bm: int = 128,
    bn: int = 128,
    bkw: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Mesh-sharded Eq. 1: the paper's cross-subarray accumulation.

    The packed contraction (KW uint32 words == K/32 input columns) is split
    across mesh ``axis`` — each shard holds a contiguous group of subarray
    rows (``core.packed.shard_packed(..., split="k")`` lays weights out this
    way) and runs the fused single-launch kernel on its resident planes.
    The per-shard int32 popcount partials then reduce losslessly via
    ``distributed.collectives.exact_psum`` — the one collective this matmul
    needs, mirroring how the paper accumulates cross-written partial sums
    across subarrays. ``shard_map`` is required because ``pallas_call`` has
    no GSPMD partitioning rule: under plain jit a sharded operand would
    silently gather.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.collectives import exact_psum, shard_map_compat

    m, k = qa.shape
    _, n, kw = pw.shape
    if k != kw * 32:
        raise ValueError(f"K={k} does not match packed weight KW={kw}")
    size = mesh.shape[axis]
    if kw % size:
        raise ValueError(
            f"packed K words {kw} not divisible by mesh axis {axis!r}={size}")

    def local(qa_l, pw_l):
        p = bitserial_matmul_fused(qa_l, pw_l, a_bits=a_bits, w_bits=w_bits,
                                   bm=bm, bn=bn, bkw=bkw, interpret=interpret)
        return exact_psum(p, axis)

    return shard_map_compat(
        local, mesh,
        in_specs=(P(None, axis), P(None, None, axis)),
        out_specs=P(None, None),
        check_rep=False,   # pallas_call has no replication rule
    )(qa, pw)
