"""Pallas TPU kernel for the paper's Eq. 1 bit-serial matmul.

Computes ``P[b, o] = sum_{n,m} 2^(n+m) * popcount(pa[n, b, :] & pw[m, o, :])``
over packed uint32 bit-planes — the NAND-SPIN subarray dataflow mapped onto
the TPU memory hierarchy:

  HBM             packed activation planes + packed weight planes
  VMEM (BlockSpec)  one (bm x bkw) activation tile per plane, one (bn x bkw)
                    weight tile per plane  (== the paper's weight buffer)
  VREG/VPU        lane-wise AND + population_count  (== sense-amp AND + column
                    bit-counter)
  VMEM accumulator  output tile revisited across the K grid axis (== the
                    paper's cross-written partial sums staying in-mat)

Grid = (m_tiles, n_tiles, k_tiles) with K innermost, so the int32 output
block stays resident in VMEM while partial popcounts accumulate — partial
sums never round-trip to HBM, which is exactly the property the paper's
cross-writing scheme buys on NAND-SPIN.

The (bm, chunk, bkw) broadcast intermediate is tiled by an inner fori_loop
over output-column chunks of 128 lanes to bound VREG/VMEM pressure
(`_OC` below); the MXU is idle in this kernel by design — Eq. 1 is a pure
VPU bit-op pipeline. See ``mxu_plane`` in :mod:`repro.core.bitserial` for
the systolic alternative, and DESIGN.md §2 for the trade-off experiment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-column chunk for the inner loop: one lane group.
_OC = 128


def _kernel(a_ref, w_ref, o_ref, *, a_bits: int, w_bits: int, bm: int, bn: int,
            bkw: int):
    # Zero the accumulator tile on the first K step (grid axis 2 innermost).
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    def oc_body(c, acc):
        # acc: (bm, bn) int32. Process output columns [c*_OC, (c+1)*_OC).
        partial = jnp.zeros((bm, _OC), jnp.int32)
        for n in range(a_bits):          # static unroll: plane pairs
            a = a_ref[n]                 # (bm, bkw) uint32
            for m in range(w_bits):
                w = jax.lax.dynamic_slice(w_ref[m], (c * _OC, 0), (_OC, bkw))
                # sense-amp AND + per-column bitcount, 32 cells per lane
                cnt = jax.lax.population_count(a[:, None, :] & w[None, :, :])
                partial += cnt.sum(-1).astype(jnp.int32) << (n + m)
        return jax.lax.dynamic_update_slice(acc, partial, (0, c * _OC))

    acc = jax.lax.fori_loop(0, bn // _OC, oc_body, jnp.zeros((bm, bn), jnp.int32))
    o_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("a_bits", "w_bits", "bm", "bn", "bkw", "interpret")
)
def bitserial_matmul_packed(
    pa: jax.Array,  # (a_bits, M, KW) uint32 packed activation planes
    pw: jax.Array,  # (w_bits, N, KW) uint32 packed weight planes
    *,
    a_bits: int,
    w_bits: int,
    bm: int = 128,
    bn: int = 128,
    bkw: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Packed-plane bit-serial matmul -> (M, N) int32."""
    _, m, kw = pa.shape
    _, n, _ = pw.shape
    bm = min(bm, m)
    bn = min(bn, n)
    bkw = min(bkw, kw)
    if m % bm or n % bn or kw % bkw or bn % _OC and bn != n:
        raise ValueError(f"shape ({m},{n},{kw}) not divisible by blocks ({bm},{bn},{bkw})")
    oc = min(_OC, bn)

    grid = (m // bm, n // bn, kw // bkw)
    kern = functools.partial(
        _kernel, a_bits=a_bits, w_bits=w_bits, bm=bm, bn=bn, bkw=bkw
    )
    # small-N fallback for the inner chunking
    if oc != _OC:
        kern = functools.partial(
            _small_kernel, a_bits=a_bits, w_bits=w_bits
        )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((a_bits, bm, bkw), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((w_bits, bn, bkw), lambda i, j, k: (0, j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(pa, pw)


def _small_kernel(a_ref, w_ref, o_ref, *, a_bits: int, w_bits: int):
    """Variant without output-column chunking for narrow outputs."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for n in range(a_bits):
        a = a_ref[n]
        for m in range(w_bits):
            w = w_ref[m]
            cnt = jax.lax.population_count(a[:, None, :] & w[None, :, :])
            acc += cnt.sum(-1).astype(jnp.int32) << (n + m)
    o_ref[...] += acc
