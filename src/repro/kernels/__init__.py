"""Pallas TPU kernels for the paper's compute hot-spot (Eq. 1).

  bitserial_matmul.py  packed AND+popcount matmul (pl.pallas_call + BlockSpec)
  bitplane_pack.py     fused bit-plane slice + lane pack
  ops.py               jit'd public wrappers (interpret=True off-TPU)
  ref.py               pure-jnp oracles
"""
from .ops import bitserial_matmul, pack_planes

__all__ = ["bitserial_matmul", "pack_planes"]
