"""Pure-jnp oracles for every Pallas kernel in this package.

These are deliberately naive (no blocking, no packing tricks beyond what the
math needs) so that a mismatch always indicts the kernel, not the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitslice


def bitserial_matmul_packed_ref(pa: jax.Array, pw: jax.Array) -> jax.Array:
    """(a_bits, M, KW) x (w_bits, N, KW) packed planes -> (M, N) int32."""
    a_bits, m, kw = pa.shape
    w_bits, n, _ = pw.shape
    out = jnp.zeros((m, n), jnp.int32)
    for nb in range(a_bits):
        for mb in range(w_bits):
            cnt = jax.lax.population_count(pa[nb][:, None, :] & pw[mb][None, :, :])
            out = out + (cnt.sum(-1).astype(jnp.int32) << (nb + mb))
    return out


def bitserial_matmul_codes_ref(qa: jax.Array, qw: jax.Array) -> jax.Array:
    """End-to-end oracle from integer codes: plain integer matmul.

    By Eq. 1 this equals the packed popcount pipeline exactly.
    """
    return jax.lax.dot_general(
        qa.astype(jnp.int32), qw.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32,
    )


def bitplane_pack_ref(q: jax.Array, bits: int) -> jax.Array:
    """(M, K) codes -> (bits, M, K//32) uint32."""
    return bitslice.pack_bits(bitslice.bitplanes(q.astype(jnp.int32), bits))


def wkv_chunked_ref(r, k, v, lw, u, s0):
    """Sequential-scan oracle for the chunked WKV kernel.

    r/k/v/lw (BH, S, D) f32 (lw = log decay <= 0); u (BH, D); s0 (BH, D, D).
    """
    w = jnp.exp(lw)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[:, :, None] * v_t[:, None, :]
        y = jnp.einsum("bk,bkv->bv", r_t, S + u[:, :, None] * kv)
        return w_t[:, :, None] * S + kv, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s_last, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_last
