"""Pallas TPU kernel: chunked-parallel RWKV-6 WKV (the rwkv perf path).

Grid = (B*H, n_chunks) with the chunk axis innermost/sequential; the
(D, D) recurrent state lives in a VMEM scratch buffer that persists across
chunk steps, so state traffic to HBM is ZERO during the sweep (the JAX
chunked form still round-trips it through the scan carry once per chunk).
Per grid step the kernel loads one (L, D) tile each of r/k/v/log-decay,
runs the cumulative-decay matmul algebra of
:func:`repro.models.lm.rwkv6._chunked_wkv` entirely in VMEM/VREGs, writes
the (L, D) output tile, and updates the scratch state; the final state is
emitted on the last chunk.

This is the TPU-native answer to RWKV's CUDA kernels: the intra-chunk
(L x L)(L x D) contractions are MXU work, the decay algebra is VPU work,
and the HBM->VMEM stream is exactly one pass over the sequence.

Validated in interpret mode against the pure-jnp chunked form and the
sequential scan oracle (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sout_ref,
            state, *, chunk: int, hd: int, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0]

    r = r_ref[0, 0].astype(jnp.float32)       # (L, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (D,)
    S = state[...]                            # (D, D) f32

    p_inc = jnp.cumsum(lw, axis=0)            # inclusive log-decay prefix
    p_prev = p_inc - lw
    r_t = r * jnp.exp(p_prev)
    k_t = k * jnp.exp(-p_inc)
    a = r_t @ k_t.T                           # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a = jnp.where(lj < li, a, 0.0)            # strict lower triangle (s < t)
    y = r_t @ S + a @ v
    y += jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v

    p_last = p_inc[-1:]                       # (1, D)
    k_rem = k * jnp.exp(p_last - p_inc)
    state[...] = jnp.exp(p_last).T * S + k_rem.T @ v
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(c == n_chunks - 1)
    def _emit():
        sout_ref[0] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_chunked(r, k, v, lw, u, s0, *, chunk: int = 16,
                interpret: bool = False):
    """Chunked WKV. r/k/v/lw: (BH, S, D) f32; u: (BH, D); s0: (BH, D, D).

    Returns (y (BH, S, D) f32, s_final (BH, D, D) f32). ``lw`` is the
    per-step log decay (<= 0, clamped as in repro.models.lm.rwkv6).
    """
    bh, s, d = r.shape
    if s % chunk:
        raise ValueError(f"S={s} not divisible by chunk={chunk}")
    nc = s // chunk
    rc, kc, vc, lwc = (t.reshape(bh, nc, chunk, d) for t in (r, k, v, lw))

    kern = functools.partial(_kernel, chunk=chunk, hd=d, n_chunks=nc)
    tile = pl.BlockSpec((1, 1, chunk, d), lambda i, c: (i, c, 0, 0))
    y, s_final = pl.pallas_call(
        kern,
        grid=(bh, nc),
        in_specs=[tile, tile, tile, tile,
                  pl.BlockSpec((1, d), lambda i, c: (i, 0)),
                  pl.BlockSpec((1, d, d), lambda i, c: (i, 0, 0))],
        out_specs=[tile, pl.BlockSpec((1, d, d), lambda i, c: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((bh, nc, chunk, d), jnp.float32),
                   jax.ShapeDtypeStruct((bh, d, d), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(rc, kc, vc, lwc, u, s0)
    return y.reshape(bh, s, d), s_final
