"""Pallas kernel: fused bit-plane slice + uint32 lane pack.

Takes integer codes (M, K) int32 (K % 32 == 0) and emits the packed planes
(bits, M, K//32) uint32 consumed by :mod:`.bitserial_matmul`. One pass over
the codes produces all planes — on NAND-SPIN this is the "program each
bit-plane into its subarray" step; on TPU it is a single VMEM-resident
shift/mask/reduce, so quantize->pack never spills intermediates to HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, o_ref, *, bits: int, bm: int, bkw: int):
    q = q_ref[...].astype(jnp.uint32)            # (bm, bkw*32)
    q = q.reshape(bm, bkw, 32)
    lane_w = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, None, :]
    for b in range(bits):                         # static unroll over planes
        plane = (q >> jnp.uint32(b)) & jnp.uint32(1)
        o_ref[b] = (plane * lane_w).sum(-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bkw", "interpret"))
def bitplane_pack(
    q: jax.Array,  # (M, K) int32 codes in [0, 2^bits)
    *,
    bits: int,
    bm: int = 256,
    bkw: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, k = q.shape
    if k % 32:
        raise ValueError("K must be a multiple of 32 (pad with zeros first)")
    kw = k // 32
    bm = min(bm, m)
    bkw = min(bkw, kw)
    if m % bm or kw % bkw:
        raise ValueError(f"({m},{kw}) not divisible by blocks ({bm},{bkw})")
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, bm=bm, bkw=bkw),
        grid=(m // bm, kw // bkw),
        in_specs=[pl.BlockSpec((bm, bkw * 32), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bits, bm, bkw), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((bits, m, kw), jnp.uint32),
        interpret=interpret,
    )(q)
