"""The paper's data-mapping scheme as a tiling planner (§4.1, Fig. 8 & 12).

On NAND-SPIN the mapping is: input bit-planes resident one-per-subarray
(256 rows x 128 cols), the weight plane in a small buffer reused across the
whole input plane (one buffer write per plane), and bit-count partial sums
"cross-written" into disjoint columns of an accumulator subarray.

On TPU the same three decisions become:
  * which operand is stationary in VMEM        -> the weight block (buffer)
  * the tile shape streamed from HBM           -> BlockSpec block shapes
  * where partial sums accumulate              -> a VMEM accumulator tile that
                                                  persists across the K grid
                                                  axis (cross-writing)

This module picks the block shapes; :mod:`repro.kernels.bitserial_matmul`
consumes them, and :mod:`repro.pim.mapper` uses the subarray variant for the
architecture simulator.
"""
from __future__ import annotations

import dataclasses
import math

LANE = 128          # TPU lane width (and the paper's subarray column count)
SUBLANE = 8         # f32/i32 sublane count
WORD_BITS = 32

# Paper subarray geometry (§5.2): 256 rows x 128 columns.
SUBARRAY_ROWS = 256
SUBARRAY_COLS = 128


@dataclasses.dataclass(frozen=True)
class TilePlan:
    bm: int            # rows of A per tile (batch-ish dim)
    bk_words: int      # packed K words per tile (bk_words * 32 input bits)
    bn: int            # output columns per tile
    grid: tuple        # (m_tiles, n_tiles, k_tiles)
    vmem_bytes: int    # working-set estimate for one grid step

    @property
    def bk_bits(self) -> int:
        return self.bk_words * WORD_BITS


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def plan_matmul(
    m: int,
    k: int,
    n: int,
    a_bits: int = 8,
    w_bits: int = 8,
    vmem_budget: int = 8 * 1024 * 1024,
) -> TilePlan:
    """Choose VMEM tile shapes for the packed bit-serial matmul.

    Heuristics (mirroring the paper's buffer-reuse argument):
      * bn is lane-aligned (128) — one output lane group per "subarray column".
      * bm is sublane-aligned (8); grow it while VMEM allows, because the
        weight tile is reused bm times per load (weight-stationary reuse).
      * bk_words covers K when possible so the accumulator never round-trips
        to HBM (the cross-writing property); otherwise K is gridded and the
        accumulator tile persists across the k grid axis.
    """
    kw = _round_up(max(k, 1), WORD_BITS) // WORD_BITS
    bn = min(_round_up(n, LANE), 512)
    bk_words = min(kw, 512)  # 512 words = 16k bits of K per step

    def ws(bm, bkw, bn_):
        a_tile = a_bits * bm * bkw * 4
        w_tile = w_bits * bn_ * bkw * 4
        acc = bm * bn_ * 4
        return a_tile + w_tile + acc

    bm = SUBLANE
    while bm < 256 and ws(bm * 2, bk_words, bn) <= vmem_budget and bm * 2 <= _round_up(m, SUBLANE):
        bm *= 2
    while ws(bm, bk_words, bn) > vmem_budget and bk_words > SUBLANE:
        bk_words //= 2
    grid = (
        math.ceil(m / bm),
        math.ceil(n / bn),
        math.ceil(kw / bk_words),
    )
    return TilePlan(bm=bm, bk_words=bk_words, bn=bn, grid=grid, vmem_bytes=ws(bm, bk_words, bn))


@dataclasses.dataclass(frozen=True)
class SubarrayPlan:
    """How one conv/matmul layer maps onto physical subarrays (paper Fig. 12)."""

    input_planes: int       # = activation bits; one subarray set per plane
    weight_planes: int      # = weight bits; broadcast through buffers
    rows_per_pass: int      # input rows resident per subarray pass
    cols: int               # output columns per subarray (bit-counters)
    passes: int             # sequential passes over the subarray grid
    and_ops: int            # total AND-plane row operations
    buffer_writes: int      # weight buffer programming events


def plan_subarrays(m: int, k: int, n: int, a_bits: int, w_bits: int,
                   rows: int = SUBARRAY_ROWS, cols: int = SUBARRAY_COLS) -> SubarrayPlan:
    """Map an (M,K,N) contraction onto the paper's subarray geometry.

    Each subarray holds one activation bit-plane tile (rows x cols bits);
    every weight plane row triggers one AND + bitcount across all columns in
    parallel (Fig. 8 step semantics).
    """
    col_tiles = math.ceil(n / cols)
    row_tiles = math.ceil(m / rows)
    k_steps = k  # one AND per contraction element row (serial rows, parallel cols)
    passes = row_tiles * col_tiles
    return SubarrayPlan(
        input_planes=a_bits,
        weight_planes=w_bits,
        rows_per_pass=min(m, rows),
        cols=min(n, cols),
        passes=passes,
        and_ops=a_bits * w_bits * k_steps * passes,
        buffer_writes=w_bits * col_tiles,
    )
