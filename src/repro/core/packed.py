"""Prepacked weights — the paper's "program subarrays once" step as a pytree.

On NAND-SPIN, weights are written into the subarrays exactly once at
deployment; every inference afterwards only streams activations. The TPU
analog is :class:`PackedWeight`: the weight's integer codes, its packed
uint32 bit-planes (the subarray image), the Eq. 2 quantization parameters
and the precomputed column sums of the affine correction, bundled as one
registered pytree so it jits, shards and scans like any parameter leaf.

``prepack`` builds it for a (K, N) matmul weight; ``prepack_conv`` for a
(KH, KW, C, O) convolution weight, which additionally carries the
channel-packed per-kernel-row planes consumed by the fused implicit-im2col
kernel (:mod:`repro.kernels.conv2d_fused`). See DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import bitslice
from .quantize import QuantParams, calibrate_minmax, dequantize, quantize


@dataclasses.dataclass(frozen=True)
class TuneDecision:
    """Autotuner verdict carried as static metadata on a packed weight.

    ``backend`` overrides the config's Eq. 1 execution strategy at use
    time; ``bm``/``bn``/``bkw`` are tile *requests* for the Pallas matmul
    kernel (legalized against the actual operand shapes by
    ``kernels.ops.matmul_tiles``, so a decision can never produce an
    illegal BlockSpec); ``conv_mode``/``bo`` steer ``pim_conv2d``'s
    lowering path and fused O-block. ``None`` fields defer to the existing
    planner/heuristic defaults — attaching ``TuneDecision()`` with only a
    backend changes dispatch and nothing else.

    Frozen + hashable: it rides the static (aux-data) side of the pytree,
    so attaching or changing it never alters leaf buffers, shardings or
    checkpoint layouts — only which compiled program consumes them.
    """

    backend: str = "popcount"
    bm: int | None = None
    bn: int | None = None
    bkw: int | None = None
    conv_mode: str | None = None   # "fused" | "im2col" (conv weights only)
    bo: int | None = None          # fused-conv O block (conv weights only)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedWeight:
    """A (K, N) weight quantized and bit-plane-packed once.

    codes     (K, N) int32   — Eq. 2 codes (the multi-bit matrix)
    planes    (bits, N, KW) uint32 — K-packed planes of ``codes.T`` (the
              subarray image the popcount/pallas backends AND against)
    col_sums  (N,) int32     — sum_k codes[k, n], precomputed for the affine
              correction (Sw in quantize.py's dot-product algebra)
    wq        QuantParams    — scale/qmin/bits of the weight quantization
    tune      TuneDecision | None — static per-weight autotuner verdict
              (repro.pim.autotune); None keeps the config-selected backend
              and planner-default tiles
    """

    codes: jax.Array
    planes: jax.Array
    col_sums: jax.Array
    wq: QuantParams
    tune: TuneDecision | None = dataclasses.field(
        metadata=dict(static=True), default=None)

    @property
    def bits(self) -> int:
        return self.wq.bits

    @property
    def shape(self) -> tuple:
        return self.codes.shape

    def to_float(self) -> jax.Array:
        """Dequantized master weight (fallback for non-quantized paths).

        Works on stacked prepacks too (scan reps and/or expert banks): every
        leading axis beyond the (K, N) matrix carries its own ``wq`` entry,
        so dequantization vmaps over the stack."""
        fn = dequantize
        for _ in range(self.codes.ndim - 2):
            fn = jax.vmap(fn)
        return fn(self.codes, self.wq)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedConvWeight:
    """A (KH, KW, C, O) conv weight prepacked for both conv lowering paths.

    mat          PackedWeight over the (KH*KW*C, O) im2col matrix — drives
                 the materialized path and the affine correction.
    fused_planes (KH, bits, O, KW, CW) uint32 — channel-packed planes per
                 kernel row, the layout the fused implicit-im2col kernel
                 streams one (kh) slab at a time.
    """

    mat: PackedWeight
    fused_planes: jax.Array
    kernel_shape: tuple = dataclasses.field(metadata=dict(static=True),
                                            default=(1, 1, 1, 1))
    tune: TuneDecision | None = dataclasses.field(
        metadata=dict(static=True), default=None)

    @property
    def bits(self) -> int:
        return self.mat.bits

    @property
    def wq(self) -> QuantParams:
        return self.mat.wq

    def to_float(self) -> jax.Array:
        return self.mat.to_float().reshape(self.kernel_shape)


def prepack(w: jax.Array, w_bits: int, mesh=None, axis: str = "model",
            split: str = "n") -> PackedWeight:
    """Quantize + bit-slice + lane-pack a (K, N) weight once.

    Everything here is jnp, so ``jax.vmap(prepack)`` prepacks scan-stacked
    (R, K, N) parameter leaves (the LM layer stack) in one shot — and
    ``jax.vmap`` again for MoE expert banks: an (E, K, N) expert stack
    packs to codes (E, K, N), planes (E, bits, N, KW), col_sums (E, N)
    with per-expert ``wq`` leaves of shape (E,), the layout
    ``shard_packed(split="e")`` deals out expert-wise (experts = the
    paper's chips) and ``moe_ffn`` contracts per expert under ``vmap``.

    ``mesh``: distribute the packed planes across a device mesh right after
    packing (the paper's banks each receiving their weight columns) — see
    :func:`shard_packed` for the ``axis``/``split`` semantics. ``mesh`` is
    an eager-only convenience (``device_put`` cannot run under a trace):
    under ``vmap``/``jit`` leave it None and call :func:`shard_packed` on
    the stacked result instead — it handles the leading reps axis.
    """
    wq = calibrate_minmax(w, w_bits)
    codes = quantize(w, wq)
    planes = bitslice.slice_and_pack(codes.T, w_bits)  # (bits, N, KW)
    out = PackedWeight(codes=codes, planes=planes,
                       col_sums=codes.sum(0).astype(jnp.int32), wq=wq)
    if mesh is not None:
        out = shard_packed(out, mesh, axis=axis, split=split)
    return out


def shard_packed(pw: PackedWeight | PackedConvWeight, mesh,
                 axis: str = "model", split: str = "n"):
    """Distribute a :class:`PackedWeight`/:class:`PackedConvWeight` across a
    device mesh.

    ``split="n"`` — the paper's *bank* mapping: output columns are dealt
    out across ``axis`` (planes split on their N dim, along with codes and
    the correction ``col_sums``); each shard's matmul is complete for its
    columns, no reduction needed. For a conv weight this is the
    output-channel (O) split: the im2col ``mat`` splits on its N dim AND
    the ``fused_planes`` on their O dim — both lowering paths land the same
    output channels on the same shard.

    ``split="k"`` — the *subarray-group* mapping: the packed contraction
    words split across ``axis`` (planes on KW, codes on K); each shard
    produces int32 partial sums that must reduce via
    ``distributed.collectives.exact_psum`` (see
    ``kernels.bitserial_matmul.bitserial_matmul_sharded``). Conv weights
    only support the bank split: their contraction dim (KH*KW*C) has no
    aligned per-kernel-row decomposition across shards.

    ``split="e"`` — the *chip* mapping for expert-stacked prepacks (a
    ``jax.vmap(prepack)`` over an (E, K, N) expert bank): whole experts are
    dealt out across ``axis``, every field — codes, planes, col_sums and
    the per-expert ``wq`` leaves — splitting on its leading E dim. Each
    shard holds complete subarray images for its experts, so the per-expert
    GEMMs run collective-free and only the token dispatch/combine
    communicates (expert parallelism; DESIGN.md §11). Requires a stacked
    prepack (codes ndim >= 3); scan-stacked expert banks ((R, E, K, N))
    split the E dim one position in.

    Dims that do not divide the axis stay replicated via the sharding-rule
    guard — which warns once per drop, so a "bank-sharded" deployment that
    actually replicated (non-divisible N or KW) is visible. Scan-stacked
    prepacks (leading reps axis) shard the same logical dims shifted by one.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import _guard

    if split not in ("n", "k", "e"):
        raise ValueError(
            f"split {split!r}: want 'n' (banks) | 'k' (subarrays) | "
            "'e' (expert chips)")
    if isinstance(pw, PackedConvWeight):
        if split != "n":
            raise ValueError(
                "PackedConvWeight shards on the bank (output-channel) "
                "mapping only; split='k' has no conv layout")
        fused_spec = _guard((None, None, axis, None, None),
                            pw.fused_planes.shape, mesh,
                            label="shard_packed:fused_planes")
        return PackedConvWeight(
            mat=shard_packed(pw.mat, mesh, axis=axis, split="n"),
            fused_planes=jax.device_put(
                pw.fused_planes, NamedSharding(mesh, fused_spec)),
            kernel_shape=pw.kernel_shape,
            tune=pw.tune,
        )

    def put(leaf, spec, field):
        stack = leaf.ndim - len(spec)          # 1 when vmap-prepacked
        spec = _guard((None,) * stack + tuple(spec), leaf.shape, mesh,
                      label=f"shard_packed:{field}")
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    if split == "e":
        if pw.codes.ndim < 3:
            raise ValueError(
                "split='e' needs an expert-stacked prepack "
                f"(codes ndim >= 3, got {pw.codes.ndim})")

        def put_e(leaf, rank, field):
            # Expert dim sits just above the per-expert logical rank; any
            # further leading dims (scan reps) stay replicated.
            pos = leaf.ndim - rank - 1
            spec = _guard((None,) * pos + (axis,) + (None,) * rank,
                          leaf.shape, mesh, label=f"shard_packed:{field}")
            return jax.device_put(leaf, NamedSharding(mesh, spec))

        return PackedWeight(
            codes=put_e(pw.codes, 2, "codes"),
            planes=put_e(pw.planes, 3, "planes"),
            col_sums=put_e(pw.col_sums, 1, "col_sums"),
            wq=jax.tree.map(lambda l: put_e(l, 0, "wq"), pw.wq),
            tune=pw.tune,
        )

    k_ax, n_ax = (axis, None) if split == "k" else (None, axis)
    return PackedWeight(
        codes=put(pw.codes, (k_ax, n_ax), "codes"),
        planes=put(pw.planes, (None, n_ax, k_ax), "planes"),
        col_sums=put(pw.col_sums, (n_ax,), "col_sums"),
        wq=jax.tree.map(
            lambda l: jax.device_put(l, NamedSharding(mesh, P())), pw.wq),
        tune=pw.tune,
    )


def repack_codes(pw: PackedWeight, codes: jax.Array) -> PackedWeight:
    """Re-program a packed weight's subarrays with new integer codes.

    Planes are re-derived from ``codes``; the digital periphery state
    (``col_sums``, ``wq``) is kept as-is. This is the primitive behind
    fault injection and spare-column repair (repro.pim.faults): the array
    image changes, the periphery's golden Sw register does not.
    """
    return PackedWeight(codes=codes,
                        planes=bitslice.slice_and_pack(codes.T, pw.bits),
                        col_sums=pw.col_sums, wq=pw.wq, tune=pw.tune)


def repack_conv_codes(pcw: PackedConvWeight, flat_codes: jax.Array
                      ) -> PackedConvWeight:
    """Conv analog of :func:`repack_codes`: new (KH*KW*C, O) im2col codes,
    both lowering layouts rebuilt so they describe the same device state."""
    kh, kw, c, o = pcw.kernel_shape
    wt = flat_codes.reshape(kh, kw, c, o).transpose(0, 3, 1, 2)
    fused = bitslice.slice_and_pack(wt, pcw.bits).transpose(1, 0, 2, 3, 4)
    return PackedConvWeight(mat=repack_codes(pcw.mat, flat_codes),
                            fused_planes=fused,
                            kernel_shape=pcw.kernel_shape, tune=pcw.tune)


def prepack_conv(w: jax.Array, w_bits: int) -> PackedConvWeight:
    """Prepack a (KH, KW, C, O) conv weight for both lowering paths."""
    kh, kw, c, o = w.shape
    wq = calibrate_minmax(w, w_bits)
    codes = quantize(w, wq)                              # (KH, KW, C, O)
    flat = codes.reshape(kh * kw * c, o)                 # im2col order
    mat = PackedWeight(
        codes=flat,
        planes=bitslice.slice_and_pack(flat.T, w_bits),
        col_sums=flat.sum(0).astype(jnp.int32),
        wq=wq,
    )
    # Fused layout: per kernel row kh, O-major, channels packed into words.
    wt = codes.transpose(0, 3, 1, 2)                     # (KH, O, KW, C)
    fused = bitslice.slice_and_pack(wt, w_bits)          # (bits, KH, O, KW, CW)
    fused = fused.transpose(1, 0, 2, 3, 4)               # (KH, bits, O, KW, CW)
    return PackedConvWeight(mat=mat, fused_planes=fused,
                            kernel_shape=(kh, kw, c, o))
