"""Bit-serial arithmetic built from AND + bitcount + shift (paper Eq. 1).

    I * W = sum_n sum_m 2^(n+m) * bitcount(AND(c_n(I), c_m(W)))

Three interchangeable execution backends, all bit-exact w.r.t. each other:

``popcount``  the paper-faithful dataflow: packed uint32 planes, lane-wise
              AND, ``population_count``, accumulate with the 2^(n+m) shift
              weights. This is what the Pallas kernel
              (:mod:`repro.kernels.bitserial_matmul`) implements with VMEM
              blocking; the version here is the XLA expression of the same
              algorithm and doubles as its oracle. The (B, N, Kp) broadcast
              is chunked over output columns (``_N_CHUNK``) so the oracle
              stays compute- rather than memory-bound.

``mxu-plane`` the TPU-codesign alternative: each (n, m) plane pair is a
              {0,1} matrix contraction, which the MXU executes natively —
              ``bitcount(AND(a, w))`` over a K axis *is* a dot product of
              0/1 vectors. Same arithmetic, systolic-array execution.

``int-direct`` reference: one integer matmul of the multi-bit codes. This is
              what Eq. 1 decomposes; used to validate the other two and as
              the fast path when the target supports int8 MXU contractions.

Accumulation is int32 and exact while ``sum_k qa*qw < 2^31`` (K up to ~32k at
<8:8>); overflow wraps identically in every backend (two's complement), so
cross-backend equivalence holds mod 2^32 unconditionally.

Weights may arrive as a :class:`repro.core.packed.PackedWeight` — the
deployment fast path where codes, planes and column sums were computed once
at prepack time (the paper's "program subarrays once"); see DESIGN.md §3.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import bitslice
from .packed import PackedWeight, prepack
from .quantize import QuantParams, affine_correction, calibrate_minmax, quantize

Backend = ("popcount", "mxu-plane", "int-direct")

# Output-column chunk of the popcount oracle: bounds the (B, chunk, Kp)
# broadcast intermediate to one lane group per step.
_N_CHUNK = 128


# ---------------------------------------------------------------------------
# Integer core: P = qa @ qw  (qa: (..., K) codes, qw: (K, N) codes)
# ---------------------------------------------------------------------------

def int_matmul_popcount_packed(pa: jax.Array, pw: jax.Array,
                               a_bits: int, w_bits: int) -> jax.Array:
    """Eq. 1 on prepacked planes. pa (a_bits, B, Kp), pw (w_bits, N, Kp).

    Output columns are processed in ``_N_CHUNK`` groups via ``lax.map`` so
    the broadcast AND intermediate is (B, _N_CHUNK, Kp), not (B, N, Kp) —
    the full-width broadcast made the XLA oracle memory-bound at large N.
    """
    b = pa.shape[1]
    n = pw.shape[1]
    nc = min(_N_CHUNK, n)
    pad = -n % nc
    if pad:
        pw = jnp.pad(pw, ((0, 0), (0, pad), (0, 0)))
    chunks = jnp.moveaxis(  # (n_chunks, w_bits, nc, Kp)
        pw.reshape(w_bits, (n + pad) // nc, nc, pw.shape[-1]), 1, 0)

    nm = jnp.stack(jnp.meshgrid(jnp.arange(a_bits), jnp.arange(w_bits),
                                indexing="ij"), -1).reshape(-1, 2)

    def one_chunk(pw_c):
        def plane_pair(carry, i):
            nb, mb = i[0], i[1]
            # The sense-amp AND against the stored plane, per-column bitcount.
            cnt = bitslice.popcount(pa[nb][:, None, :] & pw_c[mb][None, :, :]).sum(-1)
            return carry + (cnt << (nb + mb)), None

        out, _ = jax.lax.scan(plane_pair, jnp.zeros((b, nc), jnp.int32), nm)
        return out

    out = jax.lax.map(one_chunk, chunks)          # (n_chunks, B, nc)
    out = jnp.moveaxis(out, 0, 1).reshape(b, n + pad)
    return out[:, :n]


def int_matmul_popcount(qa: jax.Array, qw: jax.Array, a_bits: int, w_bits: int) -> jax.Array:
    """Eq. 1 with packed planes + popcount. qa (B, K), qw (K, N) -> (B, N) i32."""
    pa = bitslice.slice_and_pack(qa, a_bits)    # (a_bits, B, Kp)
    pw = bitslice.slice_and_pack(qw.T, w_bits)  # (w_bits, N, Kp)
    return int_matmul_popcount_packed(pa, pw, a_bits, w_bits)


def int_matmul_mxu_plane(qa: jax.Array, qw: jax.Array, a_bits: int, w_bits: int) -> jax.Array:
    """Eq. 1 with each plane pair contracted as a {0,1} matmul (MXU path)."""
    pa = bitslice.bitplanes(qa, a_bits)  # (a_bits, B, K) 0/1
    pw = bitslice.bitplanes(qw, w_bits)  # (w_bits, K, N) 0/1
    # Contract all plane pairs in one batched einsum; XLA maps each (n, m)
    # contraction onto the MXU. f32 accumulate is exact for 0/1 entries up to
    # K < 2^24; fold the 2^(n+m) shifts afterwards.
    cnt = jnp.einsum(
        "nbk,mko->nmbo", pa.astype(jnp.bfloat16), pw.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    w = bitslice.plane_weights(a_bits, w_bits)[:, :, None, None]
    return (cnt * w).sum((0, 1)).astype(jnp.int32)


def int_matmul_direct(qa: jax.Array, qw: jax.Array, a_bits: int = 0, w_bits: int = 0) -> jax.Array:
    """Direct integer contraction of the codes (what Eq. 1 decomposes)."""
    return jax.lax.dot_general(
        qa.astype(jnp.int32), qw.astype(jnp.int32),
        (((qa.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


_BACKENDS = {
    "popcount": int_matmul_popcount,
    "mxu-plane": int_matmul_mxu_plane,
    "int-direct": int_matmul_direct,
}


def int_matmul(qa, qw, a_bits, w_bits, backend="popcount"):
    if backend == "pallas":  # resolved lazily to avoid a circular import
        from repro.kernels import ops as _kops

        return _kops.bitserial_matmul(qa, qw, a_bits=a_bits, w_bits=w_bits)
    return _BACKENDS[backend](qa, qw, a_bits, w_bits)


def int_matmul_prepacked(qa: jax.Array, w: PackedWeight, a_bits: int,
                         backend: str = "popcount") -> jax.Array:
    """P = qa @ w.codes using whatever representation the backend wants.

    The popcount/pallas backends consume the prepacked planes directly —
    the weight side of quantize->slice->pack never re-runs (the in-array
    operand-reuse property the paper's subarray programming buys).

    Under an active :func:`repro.pim.faults.read_disturb_scope` every call
    sees a freshly disturbed view of the stored planes (STT-MRAM read
    disturb); the import is lazy and the check is a trace-time no-op when
    the scope is inactive, so fault-free programs lower to identical HLO.

    A :class:`~repro.core.packed.TuneDecision` attached at prepack time
    (``w.tune``, see :mod:`repro.pim.autotune`) overrides ``backend`` and
    supplies Pallas tile requests — tuning redirects dispatch only; every
    backend computes the same P bit-exactly, so the result is invariant.
    """
    from repro.pim import faults as _faults  # lazy: pim imports core

    tune = w.tune
    if tune is not None:
        backend = tune.backend
    if _faults.read_disturb_active():
        w = _faults.disturb_packed(w)
    if backend == "int-direct":
        return int_matmul_direct(qa, w.codes)
    if backend == "mxu-plane":
        return int_matmul_mxu_plane(qa, w.codes, a_bits, w.bits)
    if backend == "popcount":
        pa = bitslice.slice_and_pack(qa, a_bits)
        return int_matmul_popcount_packed(pa, w.planes, a_bits, w.bits)
    if backend == "pallas":
        from repro.kernels import ops as _kops

        tiles = {} if tune is None else dict(bm=tune.bm, bn=tune.bn,
                                             bkw=tune.bkw)
        return _kops.bitserial_matmul(qa, a_bits=a_bits, w_bits=w.bits,
                                      pw=w.planes, **tiles)
    raise ValueError(f"unknown backend {backend!r}")


# ---------------------------------------------------------------------------
# Float-facing quantized matmul (Eq. 2 calibration + Eq. 1 core + correction)
# ---------------------------------------------------------------------------

def quantized_matmul(
    a: jax.Array,                    # (..., K) float
    w,                               # (K, N) float | PackedWeight
    a_bits: int = 8,
    w_bits: int = 8,
    backend: str = "popcount",
    wq: QuantParams | None = None,
    qw: jax.Array | None = None,
) -> jax.Array:
    """Full paper pipeline: calibrate -> quantize -> bit-serial P -> dequantize.

    Weights may be a :class:`PackedWeight` (the deployment mode: codes,
    planes and column sums live in memory and only activations quantize on
    the fly — the paper's weights are programmed into subarrays once), or a
    float array, optionally with legacy pre-quantized ``wq``/``qw``.
    """
    lead = a.shape[:-1]
    k = a.shape[-1]
    a2 = a.reshape(-1, k)
    aq = calibrate_minmax(a2, a_bits)
    qa = quantize(a2, aq)
    if isinstance(w, PackedWeight):
        packed = w
    elif qw is not None:
        packed = PackedWeight(codes=qw, planes=bitslice.slice_and_pack(qw.T, wq.bits),
                              col_sums=qw.sum(0).astype(jnp.int32), wq=wq)
    else:
        packed = prepack(w, w_bits)
    p = int_matmul_prepacked(qa, packed, a_bits, backend)
    sa = qa.sum(-1, keepdims=True)
    y = affine_correction(p, sa, packed.col_sums, k, aq, packed.wq)
    return y.reshape(*lead, packed.shape[-1])
