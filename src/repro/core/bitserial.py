"""Bit-serial arithmetic built from AND + bitcount + shift (paper Eq. 1).

    I * W = sum_n sum_m 2^(n+m) * bitcount(AND(c_n(I), c_m(W)))

Three interchangeable execution backends, all bit-exact w.r.t. each other:

``popcount``  the paper-faithful dataflow: packed uint32 planes, lane-wise
              AND, ``population_count``, accumulate with the 2^(n+m) shift
              weights. This is what the Pallas kernel
              (:mod:`repro.kernels.bitserial_matmul`) implements with VMEM
              blocking; the version here is the XLA expression of the same
              algorithm and doubles as its oracle.

``mxu-plane`` the TPU-codesign alternative: each (n, m) plane pair is a
              {0,1} matrix contraction, which the MXU executes natively —
              ``bitcount(AND(a, w))`` over a K axis *is* a dot product of
              0/1 vectors. Same arithmetic, systolic-array execution.

``int-direct`` reference: one integer matmul of the multi-bit codes. This is
              what Eq. 1 decomposes; used to validate the other two and as
              the fast path when the target supports int8 MXU contractions.

Accumulation is int32 and exact while ``sum_k qa*qw < 2^31`` (K up to ~32k at
<8:8>); overflow wraps identically in every backend (two's complement), so
cross-backend equivalence holds mod 2^32 unconditionally.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import bitslice
from .quantize import QuantParams, affine_correction, calibrate_minmax, quantize

Backend = ("popcount", "mxu-plane", "int-direct")


# ---------------------------------------------------------------------------
# Integer core: P = qa @ qw  (qa: (..., K) codes, qw: (K, N) codes)
# ---------------------------------------------------------------------------

def int_matmul_popcount(qa: jax.Array, qw: jax.Array, a_bits: int, w_bits: int) -> jax.Array:
    """Eq. 1 with packed planes + popcount. qa (B, K), qw (K, N) -> (B, N) i32."""
    pa = bitslice.slice_and_pack(qa, a_bits)  # (a_bits, B, Kp)
    pw = bitslice.slice_and_pack(qw.T, w_bits)  # (w_bits, N, Kp)

    def plane_pair(carry, nm):
        n, m = nm
        a = pa[n]  # (B, Kp) uint32
        w = pw[m]  # (N, Kp) uint32
        # The sense-amp AND against the stored plane, then per-column bitcount.
        cnt = bitslice.popcount(a[:, None, :] & w[None, :, :]).sum(-1)  # (B, N)
        return carry + (cnt << (n + m)), None

    nm = jnp.stack(jnp.meshgrid(jnp.arange(a_bits), jnp.arange(w_bits), indexing="ij"), -1)
    nm = nm.reshape(-1, 2)
    init = jnp.zeros((qa.shape[0], qw.shape[1]), jnp.int32)
    out, _ = jax.lax.scan(lambda c, i: plane_pair(c, (i[0], i[1])), init, nm)
    return out


def int_matmul_mxu_plane(qa: jax.Array, qw: jax.Array, a_bits: int, w_bits: int) -> jax.Array:
    """Eq. 1 with each plane pair contracted as a {0,1} matmul (MXU path)."""
    pa = bitslice.bitplanes(qa, a_bits)  # (a_bits, B, K) 0/1
    pw = bitslice.bitplanes(qw, w_bits)  # (w_bits, K, N) 0/1
    # Contract all plane pairs in one batched einsum; XLA maps each (n, m)
    # contraction onto the MXU. f32 accumulate is exact for 0/1 entries up to
    # K < 2^24; fold the 2^(n+m) shifts afterwards.
    cnt = jnp.einsum(
        "nbk,mko->nmbo", pa.astype(jnp.bfloat16), pw.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    w = bitslice.plane_weights(a_bits, w_bits)[:, :, None, None]
    return (cnt * w).sum((0, 1)).astype(jnp.int32)


def int_matmul_direct(qa: jax.Array, qw: jax.Array, a_bits: int = 0, w_bits: int = 0) -> jax.Array:
    """Direct integer contraction of the codes (what Eq. 1 decomposes)."""
    return jax.lax.dot_general(
        qa.astype(jnp.int32), qw.astype(jnp.int32),
        (((qa.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


_BACKENDS = {
    "popcount": int_matmul_popcount,
    "mxu-plane": int_matmul_mxu_plane,
    "int-direct": int_matmul_direct,
}


def int_matmul(qa, qw, a_bits, w_bits, backend="popcount"):
    if backend == "pallas":  # resolved lazily to avoid a circular import
        from repro.kernels import ops as _kops

        return _kops.bitserial_matmul(qa, qw, a_bits=a_bits, w_bits=w_bits)
    return _BACKENDS[backend](qa, qw, a_bits, w_bits)


# ---------------------------------------------------------------------------
# Float-facing quantized matmul (Eq. 2 calibration + Eq. 1 core + correction)
# ---------------------------------------------------------------------------

def quantized_matmul(
    a: jax.Array,  # (..., K) float
    w: jax.Array,  # (K, N) float
    a_bits: int = 8,
    w_bits: int = 8,
    backend: str = "popcount",
    wq: QuantParams | None = None,
    qw: jax.Array | None = None,
) -> jax.Array:
    """Full paper pipeline: calibrate -> quantize -> bit-serial P -> dequantize.

    Weights may be pre-quantized (``wq``/``qw``) — the deployment mode where
    codes live in memory and only activations are quantized on the fly (the
    paper's weights are programmed into subarrays once).
    """
    lead = a.shape[:-1]
    k = a.shape[-1]
    a2 = a.reshape(-1, k)
    aq = calibrate_minmax(a2, a_bits)
    qa = quantize(a2, aq)
    if qw is None:
        wq = calibrate_minmax(w, w_bits)
        qw = quantize(w, wq)
    p = int_matmul(qa, qw, a_bits, w_bits, backend)
    sa = qa.sum(-1, keepdims=True)
    sw = qw.sum(0)
    y = affine_correction(p, sa, sw, k, aq, wq)
    return y.reshape(*lead, w.shape[-1])
