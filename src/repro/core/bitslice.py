"""Bit-plane decomposition and lane packing (paper §4.1, Fig. 8).

The paper stores an M-bit matrix as M 1-bit matrices, one per subarray. On
TPU the same decomposition packs each 1-bit plane 32-to-a-lane into ``uint32``
words so the VPU evaluates 32 of the paper's sense-amp AND operations per
lane per cycle, and ``population_count`` replaces the per-column bit-counter.

Layout convention: the *contraction* axis K is packed, i.e. a plane of an
``(..., K)`` integer tensor becomes ``(..., K//32)`` uint32. Planes are
stacked on a new leading axis -> ``(bits, ..., K//32)``; this mirrors the
paper's "one subarray per bit-plane" placement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE_BITS = 32


def pad_to_lanes(k: int) -> int:
    return (k + LANE_BITS - 1) // LANE_BITS * LANE_BITS


def bitplanes(q: jax.Array, bits: int) -> jax.Array:
    """Split integer codes into 1-bit planes: (..., K) -> (bits, ..., K)."""
    shifts = jnp.arange(bits, dtype=q.dtype).reshape((bits,) + (1,) * q.ndim)
    return (q[None] >> shifts) & 1


def pack_bits(bit_planes: jax.Array) -> jax.Array:
    """Pack the trailing axis of 0/1 ints into uint32 words.

    (..., K) with K % 32 == 0  ->  (..., K // 32) uint32.
    """
    k = bit_planes.shape[-1]
    if k % LANE_BITS:
        raise ValueError(f"K={k} must be a multiple of {LANE_BITS}; pad first")
    b = bit_planes.astype(jnp.uint32).reshape(*bit_planes.shape[:-1], k // LANE_BITS, LANE_BITS)
    weights = (jnp.uint32(1) << jnp.arange(LANE_BITS, dtype=jnp.uint32))
    return (b * weights).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits(packed: jax.Array, k: int) -> jax.Array:
    """Inverse of :func:`pack_bits`: (..., K//32) uint32 -> (..., K) int32."""
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*packed.shape[:-1], packed.shape[-1] * LANE_BITS)[..., :k].astype(jnp.int32)


def slice_and_pack(q: jax.Array, bits: int) -> jax.Array:
    """Quantized codes (..., K) -> packed planes (bits, ..., ceil(K/32)) uint32.

    Pads K up to a lane multiple with zeros (zeros are AND-neutral, so padding
    never perturbs popcount results — the paper's "blocked program current"
    for unselected columns is the same trick).
    """
    k = q.shape[-1]
    kp = pad_to_lanes(k)
    if kp != k:
        pad = [(0, 0)] * (q.ndim - 1) + [(0, kp - k)]
        q = jnp.pad(q, pad)
    return pack_bits(bitplanes(q, bits))


def plane_weights(a_bits: int, w_bits: int) -> jax.Array:
    """2^(n+m) weights of Eq. 1, shaped (a_bits, w_bits) f32."""
    n = jnp.arange(a_bits, dtype=jnp.float32)[:, None]
    m = jnp.arange(w_bits, dtype=jnp.float32)[None, :]
    return jnp.exp2(n + m)


def popcount(x: jax.Array) -> jax.Array:
    """Per-element population count of uint32 words -> int32."""
    return jax.lax.population_count(x).astype(jnp.int32)
