"""PIM-style quantized layers — the paper's technique as drop-in modules.

``PIMLinear``/``PIMConv2D`` are what the framework exposes to model code:
any dense projection (CNN conv, transformer QKVO/FFN) can be switched to the
paper's bit-serial execution by config (`PIMQuantConfig` on an arch config).

Execution modes:
  * training      -> fake-quant with STE (QAT; beyond-paper, see DESIGN.md)
  * inference     -> Eq. 1 bit-serial matmul on the selected backend
                     ("popcount" | "mxu-plane" | "int-direct" | "pallas")

Conv2D lowers to the same integer matmul via im2col, exactly how the paper
lowers convolution onto subarray dot products (a sliding window *is* the
row-activation schedule of Fig. 8).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .bitserial import quantized_matmul
from .quantize import calibrate_minmax, fake_quant, quantize


@dataclasses.dataclass(frozen=True)
class PIMQuantConfig:
    w_bits: int = 8
    a_bits: int = 8
    backend: str = "int-direct"  # cheapest exact backend; "popcount"/"pallas" = paper dataflow
    enabled: bool = True

    @property
    def tag(self) -> str:
        return f"<{self.w_bits}:{self.a_bits}>"


def _constrain_weight(w: jax.Array, role: str) -> jax.Array:
    """Pin a 2D weight's at-use sharding so GSPMD gathers the FSDP shards
    instead of partial-reducing the (much larger) activation outputs.

    role "io": (d_in, d_out) — d_in is FSDP-sharded at rest: gather it;
               keep d_out on the TP axis (output stays head/hidden-sharded).
    role "tp_in": (d_hidden, d_out) — d_hidden stays TP-sharded (the
               contraction's partial-sum all-reduce is the inherent TP
               collective); the FSDP axis on d_out gathers.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as sh

    mesh = sh.get_mesh()
    if mesh is None or w.ndim != 2 or "model" not in mesh.axis_names:
        return w
    tp = sh.axis_size(mesh, "model")
    if role == "tp_in":
        spec = P("model" if w.shape[0] % tp == 0 else None, None)
    else:
        spec = P(None, "model" if w.shape[1] % tp == 0 else None)
    return sh.constrain(w, spec)


def pim_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    cfg: PIMQuantConfig | None = None,
    train: bool = False,
    role: str = "io",
) -> jax.Array:
    """y = x @ w (+ b) through the paper's bit-serial pipeline.

    ``x``: (..., K) float; ``w``: (K, N) float master weights. ``role``
    picks the at-use sharding policy (see ``_constrain_weight``).
    """
    w = _constrain_weight(w, role)
    if cfg is None or not cfg.enabled:
        y = x @ w.astype(x.dtype)
    elif train:
        # QAT: quantization error in the forward pass, STE gradients.
        xq = fake_quant(x, cfg.a_bits)
        wq = fake_quant(w, cfg.w_bits)
        y = xq @ wq.astype(xq.dtype)
    else:
        y = quantized_matmul(
            x, w, a_bits=cfg.a_bits, w_bits=cfg.w_bits, backend=cfg.backend
        ).astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def _im2col(x: jax.Array, kh: int, kw: int, stride: int, padding: int) -> tuple[jax.Array, int, int]:
    """NHWC -> (N*OH*OW, KH*KW*C) patches."""
    n, h, w, c = x.shape
    x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    idx_h = stride * jnp.arange(oh)[:, None] + jnp.arange(kh)[None, :]
    idx_w = stride * jnp.arange(ow)[:, None] + jnp.arange(kw)[None, :]
    patches = x[:, idx_h[:, None, :, None], idx_w[None, :, None, :], :]
    # (n, oh, ow, kh, kw, c) -> (n*oh*ow, kh*kw*c)
    return patches.reshape(n * oh * ow, kh * kw * c), oh, ow


def pim_conv2d(
    x: jax.Array,          # NHWC
    w: jax.Array,          # (KH, KW, C, O)
    b: jax.Array | None = None,
    stride: int = 1,
    padding: int = 0,
    cfg: PIMQuantConfig | None = None,
    train: bool = False,
) -> jax.Array:
    kh, kw, c, o = w.shape
    if cfg is None or not cfg.enabled:
        y = jax.lax.conv_general_dilated(
            x, w, (stride, stride), [(padding, padding)] * 2,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        return y + b if b is not None else y
    cols, oh, ow = _im2col(x, kh, kw, stride, padding)
    y = pim_linear(cols, w.reshape(kh * kw * c, o), b, cfg, train)
    return y.reshape(x.shape[0], oh, ow, o)


def prepack_weights(w: jax.Array, cfg: PIMQuantConfig):
    """Deployment helper: quantize weights once (paper: program subarrays once).

    Returns (codes, QuantParams) for reuse with
    ``bitserial.quantized_matmul(..., wq=wq, qw=codes)``.
    """
    wq = calibrate_minmax(w, cfg.w_bits)
    return quantize(w, wq), wq
