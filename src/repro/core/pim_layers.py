"""PIM-style quantized layers — the paper's technique as drop-in modules.

``PIMLinear``/``PIMConv2D`` are what the framework exposes to model code:
any dense projection (CNN conv, transformer QKVO/FFN) can be switched to the
paper's bit-serial execution by config (`PIMQuantConfig` on an arch config).

Execution modes:
  * training      -> fake-quant with STE (QAT; beyond-paper, see DESIGN.md)
  * inference     -> Eq. 1 bit-serial matmul on the selected backend
                     ("popcount" | "mxu-plane" | "int-direct" | "pallas")

Weights may be float master arrays (quantized per call) or prepacked
:class:`PackedWeight`/:class:`PackedConvWeight` pytrees built once at
deployment by :func:`prepack_linear`/:func:`prepack_conv2d` — the paper's
"program subarrays once" step. See DESIGN.md §3.

Conv2D lowers to the same integer matmul two ways: a materialized im2col
patch matrix (cheap for 1x1 kernels and small maps), or the fused
implicit-im2col Pallas kernel that walks patch offsets inside the grid and
never builds the (N*OH*OW, KH*KW*C) matrix — exactly how the paper slides
the weight buffer over resident input planes (Fig. 8). The choice is a
shape-dispatch heuristic (:func:`fuse_conv_heuristic`) or forced via
``conv_mode``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .bitserial import int_matmul_prepacked, quantized_matmul
from .packed import PackedConvWeight, PackedWeight, prepack, prepack_conv
from .quantize import affine_correction, calibrate_minmax, fake_quant, quantize


@dataclasses.dataclass(frozen=True)
class PIMQuantConfig:
    w_bits: int = 8
    a_bits: int = 8
    backend: str = "int-direct"  # cheapest exact backend; "popcount"/"pallas" = paper dataflow
    enabled: bool = True

    @property
    def tag(self) -> str:
        return f"<{self.w_bits}:{self.a_bits}>"


def _constrain_weight(w: jax.Array, role: str) -> jax.Array:
    """Pin a 2D weight's at-use sharding so GSPMD gathers the FSDP shards
    instead of partial-reducing the (much larger) activation outputs.

    role "io": (d_in, d_out) — d_in is FSDP-sharded at rest: gather it;
               keep d_out on the TP axis (output stays head/hidden-sharded).
    role "tp_in": (d_hidden, d_out) — d_hidden stays TP-sharded (the
               contraction's partial-sum all-reduce is the inherent TP
               collective); the FSDP axis on d_out gathers.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as sh

    mesh = sh.get_mesh()
    if mesh is None or w.ndim != 2 or "model" not in mesh.axis_names:
        return w
    tp = sh.axis_size(mesh, "model")
    if role == "tp_in":
        spec = P("model" if w.shape[0] % tp == 0 else None, None)
    else:
        spec = P(None, "model" if w.shape[1] % tp == 0 else None)
    return sh.constrain(w, spec)


def prepack_linear(w: jax.Array, cfg: PIMQuantConfig) -> PackedWeight:
    """Quantize + pack a (K, N) weight once for repeated ``pim_linear`` calls."""
    return prepack(w, cfg.w_bits)


def prepack_conv2d(w: jax.Array, cfg: PIMQuantConfig) -> PackedConvWeight:
    """Quantize + pack a (KH, KW, C, O) conv weight once for ``pim_conv2d``."""
    return prepack_conv(w, cfg.w_bits)


def pim_linear(
    x: jax.Array,
    w: jax.Array | PackedWeight,
    b: jax.Array | None = None,
    cfg: PIMQuantConfig | None = None,
    train: bool = False,
    role: str = "io",
) -> jax.Array:
    """y = x @ w (+ b) through the paper's bit-serial pipeline.

    ``x``: (..., K) float; ``w``: (K, N) float master weights or a
    :class:`PackedWeight` prepacked at deployment. ``role`` picks the at-use
    sharding policy (see ``_constrain_weight``; prepacked weights keep the
    sharding they were packed with).
    """
    packed = isinstance(w, PackedWeight)
    if not packed:
        w = _constrain_weight(w, role)
    if cfg is None or not cfg.enabled:
        wf = w.to_float() if packed else w
        y = x @ wf.astype(x.dtype)
    elif train:
        # QAT: quantization error in the forward pass, STE gradients.
        # Prepacked weights are an inference artifact; train on the masters.
        xq = fake_quant(x, cfg.a_bits)
        wq = fake_quant(w.to_float() if packed else w, cfg.w_bits)
        y = xq @ wq.astype(xq.dtype)
    else:
        y = quantized_matmul(
            x, w, a_bits=cfg.a_bits, w_bits=cfg.w_bits, backend=cfg.backend
        ).astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def _im2col(x: jax.Array, kh: int, kw: int, stride: int, padding: int
            ) -> tuple[jax.Array, int, int]:
    """NHWC -> (N*OH*OW, KH*KW*C) patches (float x or integer codes)."""
    n, h, w, c = x.shape
    x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    idx_h = stride * jnp.arange(oh)[:, None] + jnp.arange(kh)[None, :]
    idx_w = stride * jnp.arange(ow)[:, None] + jnp.arange(kw)[None, :]
    patches = x[:, idx_h[:, None, :, None], idx_w[None, :, None, :], :]
    # (n, oh, ow, kh, kw, c) -> (n*oh*ow, kh*kw*c)
    return patches.reshape(n * oh * ow, kh * kw * c), oh, ow


# Fused-conv dispatch: below this patch-matrix size the materialized path's
# single big GEMM beats the fused kernel's per-row streaming.
_FUSE_MIN_BYTES = 4 << 20


def fuse_conv_heuristic(n: int, oh: int, ow: int, kh: int, kw: int, c: int,
                        backend: str) -> bool:
    """Should ``pim_conv2d`` take the fused implicit-im2col path?

    Fused pays when (a) the backend runs the paper dataflow on the Pallas
    kernels (the fused kernel *is* that dataflow; the XLA backends have no
    kernel to fuse into) and (b) the materialized (N*OH*OW, KH*KW*C) patch
    matrix is a real HBM blow-up — 1x1 kernels materialize for free (the
    patch matrix is a reshape) and tiny maps fit in cache anyway.
    """
    if backend != "pallas":
        return False
    if kh == kw == 1:
        return False
    return 4 * n * oh * ow * kh * kw * c >= _FUSE_MIN_BYTES


def pim_conv2d(
    x: jax.Array,          # NHWC
    w: jax.Array | PackedConvWeight,   # (KH, KW, C, O) or prepacked
    b: jax.Array | None = None,
    stride: int = 1,
    padding: int = 0,
    cfg: PIMQuantConfig | None = None,
    train: bool = False,
    conv_mode: str = "auto",           # "auto" | "fused" | "im2col"
) -> jax.Array:
    packed = isinstance(w, PackedConvWeight)
    kh, kw, c, o = w.kernel_shape if packed else w.shape
    if cfg is None or not cfg.enabled:
        wf = w.to_float() if packed else w
        y = jax.lax.conv_general_dilated(
            x, wf.astype(x.dtype), (stride, stride), [(padding, padding)] * 2,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        return y + b.astype(y.dtype) if b is not None else y
    if train:
        wf = w.to_float() if packed else w
        cols, oh, ow = _im2col(x, kh, kw, stride, padding)
        y = pim_linear(cols, wf.reshape(kh * kw * c, o), b, cfg, train=True)
        return y.reshape(x.shape[0], oh, ow, o)

    # -- quantized inference: one calibrate+quantize, two lowering paths ----
    from repro.distributed import sharding as _sh

    # Under the CNN serving layout (VisionEngine on a mesh) the bank
    # redistribution between two O-split convs happens here, on the input
    # map — never on the patch matrix (DESIGN.md §6); identity otherwise.
    x = _sh.constrain_cnn_conv_input(x)
    n = x.shape[0]
    # Calibrate on the REAL activations, not the padded tensor: calibrating
    # on the padded map stretched a strictly-positive range (post-ReLU
    # features) down to the padding zeros, wasting code space on values
    # that never occur. Padding enters as the zero CODE — which contributes
    # nothing to P or Sa — and the affine correction below charges padded
    # taps exactly zero, so border semantics stay exact for any input range.
    aq = calibrate_minmax(x, cfg.a_bits)
    qx = jnp.pad(quantize(x, aq),
                 ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    hp, wp = qx.shape[1], qx.shape[2]
    oh = (hp - kh) // stride + 1
    ow = (wp - kw) // stride + 1
    if not packed:
        # At-use sharding for float masters (as the old im2col->pim_linear
        # path applied); prepacked weights keep their packing-time layout.
        w = _constrain_weight(w.reshape(kh * kw * c, o), "io").reshape(w.shape)
        w = prepack_conv(w, cfg.w_bits)

    if conv_mode not in ("auto", "fused", "im2col"):
        raise ValueError(f"conv_mode {conv_mode!r}: want auto|fused|im2col")
    # A conv-level TuneDecision (repro.pim.autotune) resolves "auto" and
    # supplies the fused O-block; an explicit conv_mode still wins, and the
    # im2col matmul's backend rides on w.mat.tune inside
    # int_matmul_prepacked — tuning never changes bits, only dispatch.
    tune = w.tune
    if conv_mode == "auto" and tune is not None and tune.conv_mode:
        conv_mode = tune.conv_mode
    fused = {"fused": True, "im2col": False}.get(
        conv_mode, fuse_conv_heuristic(n, oh, ow, kh, kw, c, cfg.backend))
    if fused:
        from repro.kernels import ops as _kops

        p = _kops.conv2d_bitserial(qx, w.fused_planes, a_bits=cfg.a_bits,
                                   stride=stride,
                                   bo=tune.bo if tune is not None else None)
    else:
        qcols, _, _ = _im2col(qx, kh, kw, stride, 0)
        p = int_matmul_prepacked(qcols, w.mat, cfg.a_bits, cfg.backend)
        p = p.reshape(n, oh, ow, o)
    # Patch-wise activation code sums for the affine correction: a strided
    # box sum over the per-pixel channel sums — no patch matrix needed.
    sa = jax.lax.reduce_window(
        qx.sum(-1), jnp.int32(0), jax.lax.add, (1, kh, kw),
        (1, stride, stride), "VALID")
    if padding:
        # Padded taps contribute exactly zero to the dot product, so near
        # the border the correction's weight-code sum Sw and contraction
        # length K shrink per patch: a validity-mask pass computes both —
        # one (1, Hp, Wp, 1) x (KH, KW, 1, O) conv against the per-tap
        # channel-summed weight codes and one box count, both trivial next
        # to the conv itself. Interior patches recover col_sums / K*K*C.
        mask = jnp.pad(jnp.ones((1, x.shape[1], x.shape[2], 1), jnp.float32),
                       ((0, 0), (padding, padding), (padding, padding),
                        (0, 0)))
        wsum = w.mat.codes.reshape(kh, kw, c, o).sum(2)          # (KH, KW, O)
        sw = jax.lax.conv_general_dilated(
            mask, wsum[:, :, None, :].astype(jnp.float32),
            (stride, stride), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))          # (1,OH,OW,O)
        k_real = c * jax.lax.reduce_window(
            mask[..., 0], 0.0, jax.lax.add, (1, kh, kw),
            (1, stride, stride), "VALID")[..., None]             # (1,OH,OW,1)
    else:
        sw, k_real = w.mat.col_sums, kh * kw * c
    y = affine_correction(p, sa[..., None], sw, k_real,
                          aq, w.wq).astype(x.dtype)
    # Pin the output to the bank split (O on "model") so each shard computes
    # exactly its own output channels; identity off the CNN serving layout.
    y = _sh.constrain_cnn_conv_output(y)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y
