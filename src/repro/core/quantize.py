"""Quantization primitives from the paper (§4.2, Eqs. 2-3).

Eq. 2 (min/max affine quantization):
    Q_o = round((Q_i - Q_min) * (2^k - 1) / (Q_max - Q_min))

Dequantization is the affine inverse:  Q_i ~= Q_o * scale + Q_min  with
``scale = (Q_max - Q_min) / (2^k - 1)``.

Eq. 3 (batch normalization) is an affine transform at inference time; we fold
it into a (scale, bias) pair that the PIM pipeline applies with in-memory
addition/multiplication (here: a fused multiply-add).

The dot-product algebra used throughout the bit-serial path: with
``a = qa * sa + ma`` and ``w = qw * sw + mw`` (per-tensor affine),

    sum_k a_k w_k = sa*sw * P + sa*mw * Sa + sw*ma * Sw + K * ma * mw

where ``P = sum_k qa_k qw_k`` is the integer matmul computed bit-serially
(Eq. 1), ``Sa = sum_k qa_k`` and ``Sw = sum_k qw_k`` are cheap marginals.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Affine quantization parameters for one tensor.

    ``q = round((x - qmin) / scale)``;  ``x ~= q * scale + qmin``.
    """

    scale: jax.Array  # scalar or broadcastable, f32
    qmin: jax.Array  # scalar or broadcastable, f32 (the paper's Q_min offset)
    bits: int = dataclasses.field(metadata=dict(static=True), default=8)


def calibrate_minmax(x: jax.Array, bits: int, axis=None) -> QuantParams:
    """Paper Eq. 2 calibration: per-tensor (or per-axis) min/max."""
    qmin = jnp.min(x, axis=axis, keepdims=axis is not None)
    qmax = jnp.max(x, axis=axis, keepdims=axis is not None)
    # Guard the degenerate all-constant tensor; scale must stay positive.
    span = jnp.maximum(qmax - qmin, jnp.finfo(jnp.float32).tiny)
    scale = span.astype(jnp.float32) / float(2**bits - 1)
    return QuantParams(scale=scale, qmin=qmin.astype(jnp.float32), bits=bits)


def quantize(x: jax.Array, qp: QuantParams) -> jax.Array:
    """Eq. 2 forward: float -> unsigned integer codes in [0, 2^bits)."""
    q = jnp.round((x.astype(jnp.float32) - qp.qmin) / qp.scale)
    return jnp.clip(q, 0.0, float(2**qp.bits - 1)).astype(jnp.int32)


def dequantize(q: jax.Array, qp: QuantParams) -> jax.Array:
    return q.astype(jnp.float32) * qp.scale + qp.qmin


@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


def fake_quant(x: jax.Array, bits: int, axis=None) -> jax.Array:
    """Quantize-dequantize with a straight-through estimator.

    Used for quantization-aware *training* of PIM layers (beyond-paper: the
    paper is inference-only; QAT is what makes the technique a first-class
    feature of the training framework).
    """
    qp = calibrate_minmax(jax.lax.stop_gradient(x), bits, axis=axis)
    q = _ste_round((x - qp.qmin) / qp.scale)
    q = jnp.clip(q, 0.0, float(2**bits - 1))
    # preserve the input dtype: QAT must not promote bf16 residuals to f32
    # (scan carries are typed on the compute dtype)
    return (q * qp.scale + qp.qmin).astype(x.dtype)


def fold_batchnorm(gamma, beta, mean, var, eps=1e-5):
    """Eq. 3 as an inference-time affine: returns (scale, bias) such that
    ``y = x * scale + bias`` reproduces batch normalization."""
    inv = gamma / jnp.sqrt(var + eps)
    return inv, beta - mean * inv


def affine_correction(
    prod: jax.Array,  # integer matmul P = qa @ qw, shape (..., N)
    sa: jax.Array,  # row-sums of qa along K, shape (..., 1)
    sw: jax.Array,  # col-sums of qw along K, (N,) or broadcastable (..., N)
    k,              # contraction length: int, or broadcastable (..., 1) array
    aq: QuantParams,
    wq: QuantParams,
) -> jax.Array:
    """Recover the float dot product from integer pieces (module docstring).

    ``sw`` and ``k`` may vary per output position (broadcastable arrays):
    a spatially-padded convolution treats padded taps as contributing
    *exactly zero*, so near borders the effective weight-code sum and the
    effective contraction length shrink per patch (see ``pim_conv2d``).
    """
    p = prod.astype(jnp.float32)
    return (
        aq.scale * wq.scale * p
        + aq.scale * wq.qmin * sa.astype(jnp.float32)
        + wq.scale * aq.qmin * sw.astype(jnp.float32)
        + jnp.asarray(k, jnp.float32) * aq.qmin * wq.qmin
    )
