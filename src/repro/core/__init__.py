"""Core of the reproduction: the paper's bit-serial PIM arithmetic.

Public surface:
  quantize      — Eq. 2 affine quantization, Eq. 3 BN folding, STE fake-quant
  bitslice      — bit-plane decomposition + uint32 lane packing
  bitserial     — Eq. 1 AND+popcount matmul (popcount / mxu-plane / int-direct)
  pim_layers    — PIMLinear / PIMConv2D drop-in layers + PIMQuantConfig
  mapping       — the paper's data-mapping scheme as VMEM/subarray tile plans
"""
from .bitserial import int_matmul, int_matmul_prepacked, quantized_matmul
from .bitslice import bitplanes, pack_bits, plane_weights, popcount, slice_and_pack, unpack_bits
from .mapping import SubarrayPlan, TilePlan, plan_matmul, plan_subarrays
from .packed import (PackedConvWeight, PackedWeight, prepack, prepack_conv,
                     repack_codes, repack_conv_codes)
from .pim_layers import (
    PIMQuantConfig,
    fuse_conv_heuristic,
    pim_conv2d,
    pim_linear,
    prepack_conv2d,
    prepack_linear,
)
from .quantize import (
    QuantParams,
    affine_correction,
    calibrate_minmax,
    dequantize,
    fake_quant,
    fold_batchnorm,
    quantize,
)

__all__ = [
    "QuantParams", "affine_correction", "calibrate_minmax", "dequantize",
    "fake_quant", "fold_batchnorm", "quantize",
    "bitplanes", "pack_bits", "plane_weights", "popcount", "slice_and_pack",
    "unpack_bits",
    "int_matmul", "int_matmul_prepacked", "quantized_matmul",
    "PackedConvWeight", "PackedWeight", "prepack", "prepack_conv",
    "repack_codes", "repack_conv_codes",
    "PIMQuantConfig", "fuse_conv_heuristic", "pim_conv2d", "pim_linear",
    "prepack_conv2d", "prepack_linear",
    "SubarrayPlan", "TilePlan", "plan_matmul", "plan_subarrays",
]
