"""qwen1.5-4b — dense with QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.models.lm.config import ModelConfig

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen1.5-4b",
    source="hf:Qwen/Qwen1.5-0.5B; hf",
    notes="MHA (kv=20) with QKV bias.",
    model=ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        head_dim=128,
        d_ff=6912,
        vocab=151_936,
        qkv_bias=True,
        act="silu_gated",
        rope_theta=1_000_000.0,
        loss_chunk=512,
        remat="block",
    ),
)
