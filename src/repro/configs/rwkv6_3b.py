"""rwkv6-3b — Finch, attention-free, data-dependent decay [arXiv:2404.05892; hf].

O(1) decode state per layer ((H, 64, 64) wkv + token-shift vectors) ->
runs the long_500k shape natively.
"""
from repro.models.lm.config import ModelConfig

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="rwkv6-3b",
    source="arXiv:2404.05892; hf",
    notes="attention-free linear recurrence; squared-ReLU channel-mix; runs long_500k.",
    model=ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,          # time-mix heads = d_model / rwkv_head_dim
        n_kv_heads=40,
        d_ff=8960,
        vocab=65_536,
        block_pattern=("rwkv",),
        rwkv_head_dim=64,
        rwkv_chunk=16,     # chunked-parallel WKV (exact; §Perf iteration 1)
        norm="layernorm",
        loss_chunk=512,
        remat="block",
    ),
)
