"""Arch-config plumbing: input shapes, applicability rules, registry types.

Every assigned architecture gets one ``ArchConfig`` binding its published
``ModelConfig`` to the four assigned input shapes. ``applicable_shapes``
encodes the assignment's skip rules:

  * ``long_500k`` needs sub-quadratic attention — only recurrent/local
    archs (recurrentgemma, rwkv6) run it; full-attention archs record an
    explicit skip (DESIGN.md §Arch-applicability).
  * decode shapes lower ``serve_step`` (one token against a seq_len KV
    cache); train shapes lower ``train_step``.
"""
from __future__ import annotations

import dataclasses

from repro.models.lm.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    model: ModelConfig
    source: str                  # provenance tag from the assignment table
    notes: str = ""

    def applicable_shapes(self) -> dict:
        """shape name -> ShapeSpec | skip-reason string."""
        out = {}
        for name, spec in SHAPES.items():
            if name == "long_500k" and self.model.attends_globally:
                out[name] = ("skip: full quadratic attention cannot hold a "
                             "524288-token KV cache; sub-quadratic archs only")
            else:
                out[name] = spec
        return out

    def runnable_shapes(self) -> list:
        return [s for s in self.applicable_shapes().values()
                if isinstance(s, ShapeSpec)]
