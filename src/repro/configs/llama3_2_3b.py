"""llama3.2-3b — small llama3 [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.models.lm.config import ModelConfig

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama3.2-3b",
    source="hf:meta-llama/Llama-3.2-1B; unverified",
    notes="dense llama3-family GQA decoder.",
    model=ModelConfig(
        name="llama3.2-3b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=128_256,
        act="silu_gated",
        rope_theta=500_000.0,
        tie_embeddings=True,
        loss_chunk=512,
        remat="block",
    ),
)
