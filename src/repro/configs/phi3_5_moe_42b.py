"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE [hf:microsoft/Phi-3.5-MoE-instruct; hf]."""
from repro.models.lm.config import ModelConfig, MoEConfig

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="phi3.5-moe-42b-a6.6b",
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
    notes="16 experts top-2, GQA kv=8, SiLU-gated experts.",
    model=ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab=32064,
        moe=MoEConfig(n_experts=16, top_k=2),
        act="silu_gated",
        norm="layernorm",
        rope_theta=10_000.0,
        loss_chunk=512,
        remat="block",
    ),
)
