"""recurrentgemma-9b — RG-LRU + local attention, 2:1 [arXiv:2402.19427; unverified].

The Griffin pattern is two recurrent blocks followed by one local-attention
block; 38 layers = 12 full patterns + 2 trailing recurrent blocks. MQA
(kv=1) with head_dim 256; local window 2048. Sub-quadratic -> runs the
long_500k decode shape (O(1) recurrent state + O(window) ring KV).
"""
from repro.models.lm.config import ModelConfig

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="recurrentgemma-9b",
    source="arXiv:2402.19427; unverified",
    notes="hybrid RG-LRU/local-attn 2:1; MQA; window 2048; runs long_500k.",
    model=ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab=256_000,
        block_pattern=("rglru", "rglru", "local_attn"),
        local_window=2048,
        lru_width=4096,
        conv1d_width=4,
        act="gelu_gated",
        rope_theta=10_000.0,
        loss_chunk=512,
        remat="block",
    ),
)
