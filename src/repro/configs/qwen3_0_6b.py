"""qwen3-0.6b — qk_norm + GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.models.lm.config import ModelConfig

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3-0.6b",
    source="hf:Qwen/Qwen3-8B; hf",
    notes="per-head RMS qk_norm; GQA kv=8; tied embeddings.",
    model=ModelConfig(
        name="qwen3-0.6b",
        family="dense",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab=151_936,
        qk_norm=True,
        act="silu_gated",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        loss_chunk=512,
        remat="block",
    ),
)
