"""llama-3.2-vision-90b — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Backbone only: 100 layers total with a cross-attention layer after every 4
self-attention layers (100 = 20 x (4 self + 1 cross)). The vision tower is
a STUB — cross-attention keys/values come from precomputed patch embeddings
(B, n_image_tokens, d_model) supplied by ``input_specs``.
"""
from repro.models.lm.config import ModelConfig

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama-3.2-vision-90b",
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    notes="vlm backbone; patch-embedding stub; zero-init tanh-gated cross-attn.",
    model=ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab=128_256,
        cross_attn_every=4,
        n_image_tokens=6400,
        act="silu_gated",
        rope_theta=500_000.0,
        loss_chunk=512,
        remat="block",
    ),
)
