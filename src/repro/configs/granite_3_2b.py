"""granite-3-2b — dense GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""
from repro.models.lm.config import ModelConfig

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="granite-3-2b",
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
    notes="dense GQA decoder; 32 heads of dim 64.",
    model=ModelConfig(
        name="granite-3-2b",
        family="dense",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab=49_155,
        act="silu_gated",
        rope_theta=10_000.0,
        tie_embeddings=True,
        loss_chunk=512,
        remat="block",
    ),
)
