"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only: the EnCodec tokenizer/codebook-interleaving frontend is a
STUB — inputs arrive as precomputed frame embeddings (B, S, d_model)
(``embed_inputs=False``), per the assignment. MHA (kv=32), plain GELU FFN,
LayerNorm — the original is a standard pre-norm transformer decoder.
"""
from repro.models.lm.config import ModelConfig

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="musicgen-large",
    source="arXiv:2306.05284; hf",
    notes="audio backbone; frame-embedding stub frontend; vocab = 2048 codes.",
    model=ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab=2048,
        embed_inputs=False,
        act="gelu",
        norm="layernorm",
        rope_theta=10_000.0,
        remat="block",
    ),
)
