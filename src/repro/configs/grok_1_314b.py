"""grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1; unverified]."""
from repro.models.lm.config import ModelConfig, MoEConfig

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="grok-1-314b",
    source="hf:xai-org/grok-1; unverified",
    notes="MoE 8e top-2; GeGLU; attention/logit soft-capping at 30.",
    model=ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        vocab=131072,
        moe=MoEConfig(n_experts=8, top_k=2),
        act="gelu_gated",
        attn_softcap=30.0,
        logits_softcap=30.0,
        post_attn_norm=True,
        rope_theta=10_000.0,
        loss_chunk=512,
        remat="block",
    ),
)
