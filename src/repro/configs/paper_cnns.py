"""The paper's own benchmark models (AlexNet / VGG19 / ResNet50, §5.3).

These run two ways:
  * through the JAX CNN stack (:mod:`repro.models.cnn`) with PIM-quantized
    layers — the numerical reproduction;
  * through the PIM architecture simulator (:mod:`repro.pim`) — the
    performance/energy reproduction (Figs. 13-17, Table 3).
"""
from __future__ import annotations

import dataclasses

from repro.core.pim_layers import PIMQuantConfig


@dataclasses.dataclass(frozen=True)
class CNNBenchConfig:
    name: str
    image: int = 224
    classes: int = 1000
    pim: PIMQuantConfig = PIMQuantConfig(w_bits=8, a_bits=8, backend="int-direct")


CONFIGS = {
    "alexnet": CNNBenchConfig("alexnet"),
    "vgg19": CNNBenchConfig("vgg19"),
    "resnet50": CNNBenchConfig("resnet50"),
}

# The paper's precision sweep (Figs. 14-15).
WI_SWEEP = [(2, 2), (4, 4), (8, 8), (16, 16)]
