"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``.

Ten assigned LM-family architectures plus the paper's own CNN benchmark
models (which run through the PIM architecture simulator rather than the
JAX LM stack — see :mod:`repro.configs.paper_cnns`).
"""
from __future__ import annotations

import importlib

from .base import SHAPES, ArchConfig, ShapeSpec

_MODULES = {
    "grok-1-314b": "grok_1_314b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "musicgen-large": "musicgen_large",
    "llama3.2-3b": "llama3_2_3b",
    "qwen1.5-4b": "qwen1_5_4b",
    "qwen3-0.6b": "qwen3_0_6b",
    "granite-3-2b": "granite_3_2b",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "rwkv6-3b": "rwkv6_3b",
}

ARCH_IDS = tuple(_MODULES)
PAPER_CNNS = ("alexnet", "vgg19", "resnet50")


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {', '.join(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "PAPER_CNNS", "SHAPES", "ArchConfig", "ShapeSpec",
           "all_configs", "get_config"]
