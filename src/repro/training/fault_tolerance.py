"""Fault tolerance: preemption-safe train driver, straggler detection,
elastic restart policy.

What "fault tolerant" means concretely in this framework:

  1. *Checkpoint/restart* — ``run_resilient`` wraps the step loop: periodic
     async sharded checkpoints (repro.training.checkpoint) + deterministic
     (seed, step)-keyed data (repro.training.data) mean a preempted run
     restarts bit-identically from LATEST. Restore is elastic: a new mesh
     (fewer/more healthy hosts) re-shards via device_put.
  2. *Failure detection & retry* — step execution is supervised; a step
     that raises a device/runtime error triggers rollback to LATEST and
     re-execution with bounded exponential backoff; after ``max_failures``
     the driver surfaces the error (orchestrator restarts the job).
  3. *Straggler mitigation* — per-step wall times feed an online
     median/MAD estimator; steps slower than ``straggler_z`` robust-z are
     logged and counted. On real fleets the hook triggers hot-spare swap
     (the policy object decides); here the detector + policy are fully
     implemented and unit-tested, the swap is a callback.

The driver is synchronous-SPMD like every large JAX deployment; failures
are whole-job events (XLA collectives are not partial-failure tolerant),
which is why checkpoint cadence + restart latency are the knobs that
matter, and why they are first-class here.
"""
from __future__ import annotations

import bisect
import collections
import dataclasses
import math
import time

import jax

from . import checkpoint as ckpt


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    max_failures: int = 3
    backoff_s: float = 1.0
    straggler_z: float = 4.0
    keep_last: int = 3


@dataclasses.dataclass
class WatchdogConfig:
    """Per-dispatch supervision of a serving engine (DESIGN.md §7).

    The engine's ``step()`` becomes a supervised dispatch: an in-memory
    shadow snapshot is taken before each dispatch; a failure (injected
    fault, device runtime error, non-finite logits, or a dispatch slower
    than ``deadline_s``) rolls back to the shadow and retries under
    :class:`RestartPolicy` backoff. Once the failure budget is exhausted,
    ``degrade=True`` drops the engine to the float fallback path and keeps
    serving instead of crashing. ``snap_every``/``ckpt_dir`` additionally
    write durable disk snapshots every N successful dispatches.
    """

    deadline_s: float | None = None
    max_failures: int = 3
    backoff_s: float = 0.05
    degrade: bool = True
    snap_every: int = 0
    ckpt_dir: str | None = None
    straggler_z: float = 4.0


class StragglerDetector:
    """Online robust z-score over step times (median/MAD over a window).

    The window is a ``deque``; an order-maintained mirror gives the median
    in O(1) and each observation costs one ``insort`` + one eviction
    (O(log n) search, memmove insert) instead of the former full re-sort.
    The MAD is the k-th order statistic of ``|t - med|``, selected by a
    two-pointer merge of the two sorted runs around the median — O(window)
    per step, no per-step ``sorted()`` anywhere.
    """

    def __init__(self, z_thresh: float = 4.0, window: int = 128):
        self.z = z_thresh
        self.window = window
        self.times: collections.deque = collections.deque()
        self._sorted: list = []
        self.flagged = 0

    @staticmethod
    def _mad(s: list, med: float) -> float:
        # (len//2)-th smallest |t - med|: deviations of the sorted window
        # form two sorted runs (descending below the median, ascending
        # above); merge-select instead of building + sorting them.
        k = len(s) // 2
        lo = bisect.bisect_left(s, med) - 1
        hi = lo + 1
        dev = 0.0
        for _ in range(k + 1):
            left = med - s[lo] if lo >= 0 else math.inf
            right = s[hi] - med if hi < len(s) else math.inf
            if left <= right:
                dev, lo = left, lo - 1
            else:
                dev, hi = right, hi + 1
        return dev

    def observe(self, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 16:
            s = self._sorted
            med = s[len(s) // 2]
            # sigma floor at 5% of the median: perfectly uniform histories
            # (MAD ~ 0) must not flag ordinary jitter.
            sigma = max(1.4826 * self._mad(s, med), 0.05 * med, 1e-9)
            is_straggler = (dt - med) / sigma > self.z
            if is_straggler:
                self.flagged += 1
        self.times.append(dt)
        bisect.insort(self._sorted, dt)
        if len(self.times) > self.window:
            old = self.times.popleft()
            del self._sorted[bisect.bisect_left(self._sorted, old)]
        return is_straggler


class RestartPolicy:
    """Bounded exponential backoff; resets after sustained progress."""

    def __init__(self, max_failures: int, backoff_s: float):
        self.max_failures = max_failures
        self.backoff_s = backoff_s
        self.failures = 0
        self.last_good_step = -1

    def record_progress(self, step: int):
        if step - self.last_good_step >= 50:
            self.failures = 0
            self.last_good_step = step

    def on_failure(self) -> float:
        """Returns backoff seconds; raises if budget exhausted."""
        self.failures += 1
        if self.failures > self.max_failures:
            raise RuntimeError(
                f"exceeded {self.max_failures} failures without progress")
        return self.backoff_s * (2 ** (self.failures - 1))


def run_resilient(
    step_fn,                 # (params, opt_state, batch) -> (params, opt, metrics)
    params,
    opt_state,
    data_source,             # .batch(step) -> host batch dict
    n_steps: int,
    cfg: FTConfig,
    put_batch=None,          # host batch -> device arrays (sharding)
    on_straggler=None,       # callback(step, dt)
    on_metrics=None,         # callback(step, metrics)
    fail_injector=None,      # test hook: raises inside the loop
):
    """The resilient step loop. Returns (params, opt_state, stats)."""
    detector = StragglerDetector(cfg.straggler_z)
    policy = RestartPolicy(cfg.max_failures, cfg.backoff_s)
    put = put_batch or (lambda b: b)

    start = ckpt.latest_step(cfg.ckpt_dir)
    if start is not None:
        (params, opt_state), m = ckpt.restore(cfg.ckpt_dir, (params, opt_state))
        step = m["step"] + 1
    else:
        step = 0

    stats = {"restarts": 0, "stragglers": 0, "steps_run": 0}
    while step < n_steps:
        try:
            # Monotonic: straggler/deadline accounting must not see an NTP
            # wall-clock step as a multi-second stall (or a negative time).
            t0 = time.monotonic()
            if fail_injector is not None:
                fail_injector(step)
            batch = put(data_source.batch(step))
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            if detector.observe(dt):
                stats["stragglers"] += 1
                if on_straggler:
                    on_straggler(step, dt)
            if on_metrics:
                on_metrics(step, metrics)
            if step % cfg.ckpt_every == 0 and step > 0:
                ckpt.save_async(cfg.ckpt_dir, step, (params, opt_state))
            policy.record_progress(step)
            stats["steps_run"] += 1
            step += 1
        except (RuntimeError, jax.errors.JaxRuntimeError) as e:
            print(f"[fault-tolerance] step {step} failed: {e!r}", flush=True)
            wait = policy.on_failure()
            stats["restarts"] += 1
            time.sleep(min(wait, 0.05))  # bounded for tests; real: full wait
            last = ckpt.latest_step(cfg.ckpt_dir)
            if last is not None:
                (params, opt_state), m = ckpt.restore(cfg.ckpt_dir, (params, opt_state))
                step = m["step"] + 1
            else:
                step = 0
    ckpt.wait_pending()
    ckpt.save(cfg.ckpt_dir, n_steps - 1, (params, opt_state))
    return params, opt_state, stats
