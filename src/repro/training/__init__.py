from .optimizer import OptimizerConfig, apply_updates, init_opt_state
from .train_loop import make_train_step

__all__ = ["OptimizerConfig", "apply_updates", "init_opt_state", "make_train_step"]
