"""Train-step factory: loss -> grad -> AdamW, with microbatch accumulation.

``make_train_step(model_cfg, opt_cfg, accum)`` returns a pure function
``step(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable
for ``jax.jit`` with explicit in/out shardings (see repro.launch.train and
repro.launch.dryrun). Gradient accumulation runs as a ``jax.lax.scan`` over
microbatches so peak activation memory is one microbatch regardless of the
global batch; the paper-scale meshes rely on this plus per-block remat.

Cross-pod gradient compression (int8 + error feedback) lives in
:mod:`repro.distributed.collectives` and wraps the grad pytree when enabled.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.lm import loss_fn
from repro.models.lm.config import ModelConfig

from .optimizer import OptimizerConfig, apply_updates


def _split_microbatches(batch, accum: int):
    """(B, ...) -> (accum, B/accum, ...) for every leaf."""
    return jax.tree.map(
        lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch)


def make_loss_fn(model_cfg: ModelConfig):
    def _loss(params, batch):
        return loss_fn(params, model_cfg, batch, train=True)
    return _loss


def make_train_step(model_cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    accum: int = 1, compress_grads=None):
    """Returns step(params, opt_state, batch)."""
    loss = make_loss_fn(model_cfg)

    def step(params, opt_state, batch):
        if accum > 1:
            micro = _split_microbatches(batch, accum)

            def accum_body(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, total_loss), _ = jax.lax.scan(accum_body, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss_val = total_loss / accum
        else:
            loss_val, grads = jax.value_and_grad(loss)(params, batch)

        if compress_grads is not None:
            grads = compress_grads(grads)

        params, opt_state, metrics = apply_updates(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss_val
        return params, opt_state, metrics

    return step
