"""AdamW with warmup-cosine schedule and global-norm clipping, from scratch.

Optimizer state mirrors parameter sharding exactly (``m``/``v``/``master``
inherit each param's PartitionSpec), so FSDP on the "data" axis shards the
3x-f32 state the same way it shards params — the ZeRO-3 memory layout.

``master`` keeps f32 copies when params train in bf16 (mixed precision);
set ``keep_master=False`` for pure-f32 training to drop the third copy.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    keep_master: bool = True


def schedule(cfg: OptimizerConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(cfg: OptimizerConfig, params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.keep_master:
        # jnp.array (not astype): f32 params must COPY, or param/master
        # would alias one buffer and double-donation breaks the train step.
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32), params)
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """Weight decay only on matrices (skip norms, biases, scalars)."""
    name = next((k.key for k in reversed(path) if hasattr(k, "key")), "")
    return name not in ("scale", "bias", "lam", "b_a", "b_i", "w0", "u",
                        "ln_scale", "mu", "bq", "bk", "bv", "gate")


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    masters = state.get("master", params)

    def upd(path, p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        base = master.astype(jnp.float32)
        if _decay_mask(path):
            u = u + cfg.weight_decay * base
        new_master = base - lr * u
        return new_master, m, v

    flat = jax.tree_util.tree_map_with_path(upd, params, grads, state["m"],
                                            state["v"], masters)
    new_master = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))

    new_params = jax.tree.map(lambda nm, p: nm.astype(p.dtype), new_master, params)
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.keep_master:
        new_state["master"] = new_master
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
