"""Sharded, atomic, restartable checkpoints — pure numpy, no orbax.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json       tree structure, shapes, dtypes, step, mesh tag
        shard_<i>.npz       flat leaves, chunked ~512 MB per file
    <dir>/LATEST            atomic pointer (written last)

Fault-tolerance contract:
  * atomic publish — data is fully written and fsynced before LATEST flips,
    so a crash mid-save never corrupts the restore point;
  * elastic restore — leaves are stored unsharded (gathered), so a restart
    may use a different mesh/topology: ``restore(..., shardings=...)``
    re-shards via ``jax.device_put`` on the new mesh;
  * async save — ``save_async`` snapshots to host then writes on a worker
    thread, so the train loop lingers only for the device->host copy.

At 1000+ nodes each host would write only its addressable shards; the
single-process container exercises the same code path with n_hosts = 1.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading

import jax
import numpy as np

_MAX_SHARD_BYTES = 512 * 1024**2


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_paths(tree):
    return [jax.tree_util.keystr(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None):
    """Blocking save. ``tree``: arbitrary pytree of arrays."""
    leaves, _ = _flatten(tree)
    host = [np.asarray(l) for l in leaves]
    _write(ckpt_dir, step, host, _tree_paths(tree), extra or {})


_PENDING: list = []


def save_async(ckpt_dir: str, step: int, tree, extra: dict | None = None):
    """Device->host copy now; disk write on a daemon thread."""
    leaves, _ = _flatten(tree)
    host = [np.asarray(l) for l in leaves]          # sync point
    paths = _tree_paths(tree)
    t = threading.Thread(
        target=_write, args=(ckpt_dir, step, host, paths, extra or {}),
        daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def _jsonable(obj):
    """Manifest-safe ``extra``: numpy scalars/arrays -> python natives.

    Serving snapshots carry per-slot bookkeeping (np.int32 budgets, token
    arrays) in ``extra``; json.dump rejects numpy types, so sanitize at the
    write boundary rather than at every call site."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def _write(ckpt_dir: str, step: int, host_leaves, paths, extra):
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_save_")
    try:
        shards, cur, cur_bytes = [], {}, 0
        for i, arr in enumerate(host_leaves):
            cur[f"leaf_{i}"] = arr
            cur_bytes += arr.nbytes
            if cur_bytes >= _MAX_SHARD_BYTES:
                shards.append(cur)
                cur, cur_bytes = {}, 0
        if cur:
            shards.append(cur)
        for si, shard in enumerate(shards):
            np.savez(os.path.join(tmp, f"shard_{si}.npz"), **shard)
        manifest = {
            "step": step,
            "paths": paths,
            "n_leaves": len(host_leaves),
            "n_shards": len(shards),
            "extra": _jsonable(extra),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # Atomic pointer flip — the publish step.
        ptr = os.path.join(ckpt_dir, "LATEST")
        with open(ptr + ".tmp", "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.replace(ptr + ".tmp", ptr)
    except (KeyboardInterrupt, SystemExit):
        # Propagate immediately: a Ctrl-C / interpreter exit mid-save must
        # not be delayed (or masked by a cleanup failure). The orphaned tmp
        # dir is harmless — LATEST never points at it.
        raise
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(ckpt_dir: str) -> int | None:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(ckpt_dir, name, "manifest.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)["step"]


def restore(ckpt_dir: str, like_tree, step: int | None = None,
            shardings=None) -> tuple:
    """Restore into the structure of ``like_tree``; returns (tree, manifest).

    ``shardings``: optional matching pytree of NamedShardings — enables
    elastic restore onto a different mesh than the one that saved.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for si in range(manifest["n_shards"]):
        with np.load(os.path.join(d, f"shard_{si}.npz")) as z:
            flat.update({k: z[k] for k in z.files})
    leaves = [flat[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    _, treedef = jax.tree_util.tree_flatten(like_tree)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest
