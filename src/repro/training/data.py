"""Deterministic, restartable data pipeline.

Two sources behind one iterator interface:

  * ``SyntheticLM`` — endless stream of structured pseudo-text (a mixture
    of Zipfian unigrams and repeated n-gram motifs, so a model can actually
    reduce loss on it; pure-noise tokens would leave nothing to learn).
  * ``MemmapTokens`` — a flat binary token file (np.memmap), the standard
    packed-corpus format.

Determinism/restart contract: batch content is a pure function of
``(seed, step)`` — resuming from a checkpoint at step K reproduces exactly
the batches a non-preempted run would have seen. That is the property the
fault-tolerance layer relies on (no data-state checkpointing needed beyond
the step counter).

Sharded loading: each data-parallel host materializes only its slice
(``host_slice``); the global batch is assembled by the runtime from
per-host shards (jax.make_array_from_process_local_data in multi-host
deployments; single-process tests get the whole batch).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"       # synthetic | memmap
    path: str = ""                  # for memmap
    motif_len: int = 16
    n_motifs: int = 256


class SyntheticLM:
    """Zipf unigrams + recurring motifs; ~55% of positions are motif tokens."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.motifs = rng.integers(
            0, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len), dtype=np.int32)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.unigram = p / p.sum()

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len + 1
        toks = rng.choice(cfg.vocab, size=(b, s), p=self.unigram).astype(np.int32)
        # Overwrite random spans with motifs (predictable structure).
        n_spans = max(1, s // (2 * cfg.motif_len))
        for i in range(b):
            starts = rng.integers(0, s - cfg.motif_len, size=n_spans)
            ids = rng.integers(0, cfg.n_motifs, size=n_spans)
            for st, mid in zip(starts, ids):
                toks[i, st:st + cfg.motif_len] = self.motifs[mid]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_slice(self, step: int, host_index: int, n_hosts: int) -> dict:
        full = self.batch(step)
        per = self.cfg.global_batch // n_hosts
        sl = slice(host_index * per, (host_index + 1) * per)
        return {k: v[sl] for k, v in full.items()}


class MemmapTokens:
    """Packed token file; batch (seed, step) -> deterministic offsets."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self.n = len(self.data) - cfg.seq_len - 1
        if self.n <= 0:
            raise ValueError(f"{cfg.path} shorter than one sequence")

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        offs = rng.integers(0, self.n, size=cfg.global_batch)
        rows = np.stack([self.data[o:o + cfg.seq_len + 1] for o in offs])
        rows = np.asarray(rows, dtype=np.int32) % cfg.vocab
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def host_slice(self, step: int, host_index: int, n_hosts: int) -> dict:
        full = self.batch(step)
        per = self.cfg.global_batch // n_hosts
        sl = slice(host_index * per, (host_index + 1) * per)
        return {k: v[sl] for k, v in full.items()}


def make_source(cfg: DataConfig):
    if cfg.source == "synthetic":
        return SyntheticLM(cfg)
    if cfg.source == "memmap":
        return MemmapTokens(cfg)
    raise ValueError(cfg.source)
