"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires together configs -> mesh -> sharded init -> resilient step loop
(checkpoint/restart, straggler detection) -> metrics log. On this CPU
container it runs reduced configs end-to-end; on a real fleet the same
entry point runs the full configs (jax.distributed handles multi-host).

Example (CPU, ~100M-param reduced llama):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
      --steps 200 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models.lm import init as model_init
from repro.models.lm.model import cast_params
from repro.training.data import DataConfig, make_source
from repro.training.fault_tolerance import FTConfig, run_resilient
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import make_train_step


def build(arch_id: str, reduced: bool, batch: int, seq: int, steps: int,
          lr: float, accum: int, production_mesh: bool, pim: bool = False):
    arch = get_config(arch_id)
    cfg = arch.model.reduced() if reduced else arch.model
    if pim:
        from repro.core.pim_layers import PIMQuantConfig
        import dataclasses
        cfg = dataclasses.replace(cfg, pim=PIMQuantConfig(backend="int-direct"))
    mesh = make_production_mesh() if production_mesh else make_test_mesh()
    sh.set_mesh(mesh)
    sh.set_tied_embeddings(cfg.tie_embeddings)

    key = jax.random.PRNGKey(0)
    params = cast_params(model_init(cfg, key), jnp.dtype(cfg.dtype))
    p_sh = sh.param_shardings(params, mesh)
    params = jax.device_put(params, p_sh)

    ocfg = OptimizerConfig(lr=lr, warmup_steps=min(100, steps // 10 + 1),
                           total_steps=steps)
    opt_state = init_opt_state(ocfg, params)
    o_sh = sh.param_shardings(opt_state, mesh)
    o_sh["step"] = sh.replicated(mesh)
    opt_state = jax.device_put(opt_state, o_sh)

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
    source = make_source(dcfg)
    b_example = source.batch(0)
    b_sh = sh.batch_shardings(b_example, mesh, batch)

    step = jax.jit(
        make_train_step(cfg, ocfg, accum=accum),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )

    def put(host_batch):
        return jax.tree.map(
            lambda x, s: jax.device_put(jnp.asarray(x), s), host_batch, b_sh)

    return cfg, mesh, params, opt_state, step, source, put


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--pim", action="store_true",
                    help="run projections through the bit-serial PIM pipeline")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg, mesh, params, opt_state, step, source, put = build(
        args.arch, args.reduced, args.batch, args.seq, args.steps, args.lr,
        args.accum, args.production_mesh, args.pim)

    print(f"arch={args.arch} reduced={args.reduced} mesh={dict(mesh.shape)} "
          f"params={sum(l.size for l in jax.tree.leaves(params)):,}")

    history = []

    def on_metrics(s, m):
        if s % args.log_every == 0:
            loss = float(m["loss"])
            history.append((s, loss))
            print(f"step {s:5d}  loss {loss:.4f}  gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e}", flush=True)

    ft = FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    t0 = time.time()
    params, opt_state, stats = run_resilient(
        step, params, opt_state, source, args.steps, ft,
        put_batch=put, on_metrics=on_metrics)
    dt = time.time() - t0
    print(f"done: {stats} in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")
    if len(history) >= 2:
        print(f"loss: first {history[0][1]:.4f} -> last {history[-1][1]:.4f}")


if __name__ == "__main__":
    main()
