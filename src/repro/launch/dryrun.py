import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init, and the production meshes need 512 host devices.

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod or 2x16x16
multi-pod), constructs ShapeDtypeStruct stand-ins for params / optimizer
state / inputs with their production shardings, lowers the right step
function (train_step for train shapes, prefill/serve_step for inference
shapes), compiles it, and records memory + cost + collective analysis into
results/dryrun/<arch>_<shape>_<mesh>.json — the raw material for
EXPERIMENTS.md §Dry-run and §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
__doc__ = _DOC
# NOTE: no `from __future__ import annotations` here — future imports must be
# the first statement in a file, and the XLA_FLAGS lines must come first.

import argparse
import dataclasses
import json
import math
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models.lm import (
    abstract_params, decode_step, init_state, param_count, prefill,
)
from repro.roofline import analysis as roofline
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _sds(tree):
    """Concrete-or-abstract tree -> ShapeDtypeStructs."""
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


_ACCUM_OVERRIDE = [None]  # set by --accum (perf variants)


def _accum_for(shape: ShapeSpec, mesh) -> int:
    """Grad-accumulation factor: target <= 2 sequences per data shard."""
    if _ACCUM_OVERRIDE[0]:
        return _ACCUM_OVERRIDE[0]
    dp = sh.axis_size(mesh, *sh.dp_axes(mesh))
    per_shard = shape.global_batch // max(dp, 1)
    return max(1, per_shard // 2)


def input_specs(arch: ArchConfig, shape: ShapeSpec, mesh):
    """ShapeDtypeStructs + shardings for every model input of this cell."""
    cfg = arch.model
    b, s = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.dtype)
    batch = {}
    if shape.kind == "train":
        if cfg.embed_inputs:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif shape.kind == "prefill":
        if cfg.embed_inputs:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)
    else:  # decode: one new token against a seq_len-deep cache
        if cfg.embed_inputs:
            batch["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, 1, cfg.d_model), dtype)
    if cfg.cross_attn_every:
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_image_tokens, cfg.d_model), dtype)
    shardings = sh.batch_shardings(batch, mesh, b)
    return batch, shardings


def _count_arrays_bytes(tree) -> int:
    return sum(math.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(tree))


def _pim_accounting(cfg, params_abs) -> dict:
    """Deployment-time packed-plane accounting (abstract, via eval_shape).

    Mirrors what the engine's prepack would allocate on the serving fleet:
    per-leaf uint32 bit-plane and int32 code bytes, with MoE expert banks
    (``w_in``/``w_out``/``w_gate`` under a router-bearing ffn — packed one
    vmap level deeper, (E, d, f) per layer) broken out so the dry-run's
    capacity math covers the paper's subarray images for *every* expert,
    not just the top-k active ones.
    """
    from repro.core.packed import PackedWeight
    from repro.models.lm.model import prepack_params

    packed = jax.eval_shape(lambda p: prepack_params(p, cfg.pim), params_abs)
    flat = jax.tree_util.tree_flatten_with_path(
        packed, is_leaf=lambda x: isinstance(x, PackedWeight))[0]
    out = {"packed_leaves": 0, "plane_bytes": 0, "code_bytes": 0,
           "expert_banks": 0, "expert_plane_bytes": 0}
    for path, leaf in flat:
        if not isinstance(leaf, PackedWeight):
            continue
        pb = math.prod(leaf.planes.shape) * leaf.planes.dtype.itemsize
        out["packed_leaves"] += 1
        out["plane_bytes"] += pb
        out["code_bytes"] += (math.prod(leaf.codes.shape)
                              * leaf.codes.dtype.itemsize)
        keys = [getattr(k, "key", None) for k in path]
        if cfg.moe and "ffn" in keys:
            out["expert_banks"] += 1
            out["expert_plane_bytes"] += pb
    return out


def lower_cell(arch: ArchConfig, shape: ShapeSpec, mesh, verbose=True):
    """Lower + compile one cell; returns (compiled, report dict)."""
    cfg = arch.model
    chips = math.prod(mesh.devices.shape)
    sh.set_mesh(mesh)
    sh.set_tied_embeddings(cfg.tie_embeddings)
    dtype = jnp.dtype(cfg.dtype)

    params_abs = abstract_params(cfg, dtype)
    p_shard = sh.param_shardings(params_abs, mesh)
    batch, b_shard = input_specs(arch, shape, mesh)
    n_params = param_count(cfg)
    n_active = (cfg.n_active_params() if cfg.moe else n_params)

    t0 = time.time()
    if shape.kind == "train":
        ocfg = OptimizerConfig()
        opt_abs = jax.eval_shape(partial(init_opt_state, ocfg), params_abs)
        o_shard = sh.param_shardings(opt_abs, mesh)
        o_shard["step"] = sh.replicated(mesh)
        accum = _accum_for(shape, mesh)
        step = make_train_step(cfg, ocfg, accum=accum)
        metric_shard = {"loss": sh.replicated(mesh), "grad_norm": sh.replicated(mesh),
                        "lr": sh.replicated(mesh)}
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, metric_shard),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_abs, opt_abs, _sds(batch))
        tokens = shape.global_batch * shape.seq_len
        model_flops = roofline.train_model_flops(n_active, tokens)
        extra = {"accum": accum}
    else:
        max_len = shape.seq_len
        state_abs = jax.eval_shape(
            partial(init_state, cfg, shape.global_batch, max_len))
        s_shard = sh.state_shardings(state_abs, mesh, shape.global_batch)
        logits_spec = P(sh.dp_axes(mesh)
                        if shape.global_batch % sh.axis_size(mesh, *sh.dp_axes(mesh)) == 0
                        else None, None,
                        "model" if cfg.vocab % sh.axis_size(mesh, "model") == 0 else None)
        logit_shard = NamedSharding(mesh, logits_spec)

        if shape.kind == "prefill":
            def fn(params, tokens, state, image_embeds=None):
                return prefill(params, cfg, tokens, state, image_embeds=image_embeds)
        else:
            def fn(params, tokens, state, image_embeds=None):
                return decode_step(params, cfg, tokens, state, image_embeds=image_embeds)

        args = [params_abs, batch["tokens"], state_abs]
        in_sh = [p_shard, b_shard["tokens"], s_shard]
        if cfg.cross_attn_every:
            args.append(batch["image_embeds"])
            in_sh.append(b_shard["image_embeds"])
        jitted = jax.jit(
            fn,
            in_shardings=tuple(in_sh),
            out_shardings=(logit_shard, s_shard),
            donate_argnums=(2,),
        )
        lowered = jitted.lower(*[_sds(a) if not isinstance(a, jax.ShapeDtypeStruct)
                                 else a for a in args])
        n_tok = shape.global_batch * (shape.seq_len if shape.kind == "prefill" else 1)
        model_flops = roofline.decode_model_flops(n_active, n_tok)
        extra = {"state_bytes": _count_arrays_bytes(state_abs)}

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    hlo_text = compiled.as_text()
    rf = roofline.from_compiled(compiled, chips, model_flops)
    from repro.roofline import hlo_cost as _hc
    cost = _hc.analyze(hlo_text)
    try:
        xla_ca = compiled.cost_analysis()
        if isinstance(xla_ca, (list, tuple)):
            xla_ca = xla_ca[0]
        xla_ca = {k: float(v) for k, v in xla_ca.items()
                  if k in ("flops", "bytes accessed")}
    except Exception:
        xla_ca = {}
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {k: int(getattr(ma, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes") if hasattr(ma, k)}
    except Exception as e:  # XLA:CPU may not implement it
        mem = {"error": str(e)}

    report = {
        "arch": arch.arch_id,
        "shape": shape.name,
        "mesh": "x".join(map(str, mesh.devices.shape)) + ":" + ",".join(mesh.axis_names),
        "chips": chips,
        "n_params": n_params,
        "n_active_params": n_active,
        "param_bytes_per_chip": _count_arrays_bytes(params_abs) / chips,
        "pim": (_pim_accounting(cfg, params_abs)
                if getattr(cfg.pim, "enabled", False) else None),
        "roofline": rf.report(),
        "collectives": {"op_counts": cost.coll_counts,
                        "bytes_by_kind": cost.coll_bytes,
                        "wire_bytes_per_chip": cost.wire_bytes,
                        "unknown_trip_loops": cost.unknown_loops},
        "xla_cost_analysis_raw": xla_ca,
        "memory_analysis": mem,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        **extra,
    }
    if verbose:
        r = report["roofline"]
        print(f"[{arch.arch_id} / {shape.name} / {report['mesh']}] "
              f"compile {t_compile:.0f}s  bottleneck={r['bottleneck']} "
              f"t=(c {r['t_compute_s']:.3e}, m {r['t_memory_s']:.3e}, "
              f"n {r['t_collective_s']:.3e})s  roofline_frac={r['roofline_fraction']:.2f}")
        if mem:
            print("  memory_analysis:", mem)
        print("  cost_analysis: flops/chip=%.3e bytes/chip=%.3e" %
              (r["flops_per_chip"], r["hbm_bytes_per_chip"]))
    return compiled, report


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, out_dir: str,
             skip_existing: bool = False, save_hlo: bool = False,
             overrides: dict | None = None, tag: str = "") -> dict | None:
    arch = get_config(arch_id)
    if overrides:
        arch = dataclasses.replace(
            arch, model=dataclasses.replace(arch.model, **overrides))
    applicable = arch.applicable_shapes()[shape_name]
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out_path = os.path.join(
        out_dir, f"{arch_id}__{shape_name}__{mesh_tag}{suffix}.json")
    if skip_existing and os.path.exists(out_path):
        print(f"[skip existing] {out_path}")
        return None
    if isinstance(applicable, str):
        report = {"arch": arch_id, "shape": shape_name, "mesh": mesh_tag,
                  "skipped": applicable}
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"[{arch_id} / {shape_name}] SKIPPED: {applicable}")
        return report
    mesh = make_production_mesh(multi_pod=multi_pod)
    compiled, report = lower_cell(arch, applicable, mesh)
    if tag:
        report["variant"] = tag
        report["overrides"] = {k: str(v) for k, v in (overrides or {}).items()}
    if save_hlo:
        import gzip
        with gzip.open(out_path.replace(".json", ".hlo.txt.gz"), "wt") as f:
            f.write(compiled.as_text())
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--set", action="append", default=[],
                    help="model-config override field=value (perf variants)")
    ap.add_argument("--tag", default="",
                    help="variant tag appended to the result filename")
    ap.add_argument("--accum", type=int, default=0,
                    help="override grad-accumulation factor (perf variants)")
    args = ap.parse_args()
    if args.accum:
        _ACCUM_OVERRIDE[0] = args.accum

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = (v if not v.lstrip("-").isdigit() else int(v)) \
            if v not in ("true", "false") else v == "true"

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = []
    for a, s, mp in cells:
        try:
            run_cell(a, s, mp, args.out, skip_existing=args.skip_existing,
                     save_hlo=args.save_hlo, overrides=overrides, tag=args.tag)
        except Exception as e:
            print(f"[FAIL] {a}/{s}/{'2x16x16' if mp else '16x16'}: {e!r}")
            failures.append((a, s, mp, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nAll dry-run cells passed.")


if __name__ == "__main__":
    main()
