"""Serving launcher: batched generation with the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import init as model_init
from repro.models.lm.model import cast_params
from repro.serving import Request, SamplerConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    arch = get_config(args.arch)
    cfg = arch.model.reduced() if args.reduced else arch.model
    if not cfg.embed_inputs or cfg.cross_attn_every:
        raise SystemExit("serve launcher drives token-in archs; "
                         "musicgen/vlm need frontend-stub drivers (see examples)")
    params = cast_params(model_init(cfg, jax.random.PRNGKey(0)),
                         jnp.dtype(cfg.dtype))
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      max_len=args.max_len,
                      sampler=SamplerConfig(temperature=args.temperature))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        L = int(rng.integers(4, 17))
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab, size=L).astype(np.int32), max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(c.tokens) for c in done)
    for c in sorted(done, key=lambda c: c.rid):
        print(f"req {c.rid}: {len(c.tokens)} tokens -> {c.tokens[:8]}...")
    print(f"{len(done)} completions, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
