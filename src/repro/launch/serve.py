"""Serving launcher: batched generation with the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 6 --max-new 16

Multi-device serving maps the paper's chip→bank hierarchy onto a
("data", "model") mesh (DESIGN.md §5): ``--model-par N`` puts N-way
tensor/bank parallelism on the "model" axis and shards the decode-slot
grid across the rest of the devices on "data". On a CPU-only box, force a
multi-device host *before any jax import* (XLA reads the flag at backend
init):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --model-par 2 --max-batch 8

With a single device (and the default ``--model-par 1``) the engine runs
exactly as before — mesh-free.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_serve_mesh
from repro.models.lm import init as model_init
from repro.models.lm.model import cast_params
from repro.serving import Request, SamplerConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--model-par", type=int, default=1,
                    help="devices per model replica (the mesh's 'model' "
                    "axis); the rest shard decode slots on 'data'")
    args = ap.parse_args()

    arch = get_config(args.arch)
    cfg = arch.model.reduced() if args.reduced else arch.model
    if not cfg.embed_inputs or cfg.cross_attn_every:
        raise SystemExit("serve launcher drives token-in archs; "
                         "musicgen/vlm need frontend-stub drivers (see examples)")
    mesh = None
    if len(jax.devices()) > 1 or args.model_par > 1:
        mesh = make_serve_mesh(args.model_par)
        print(f"serving on mesh {dict(mesh.shape)} "
              f"({len(mesh.devices.ravel())} devices)")
    params = cast_params(model_init(cfg, jax.random.PRNGKey(0)),
                         jnp.dtype(cfg.dtype))
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      max_len=args.max_len,
                      sampler=SamplerConfig(temperature=args.temperature),
                      mesh=mesh)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        L = int(rng.integers(4, 17))
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab, size=L).astype(np.int32), max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(c.tokens) for c in done)
    for c in sorted(done, key=lambda c: c.rid):
        print(f"req {c.rid}: {len(c.tokens)} tokens -> {c.tokens[:8]}...")
    print(f"{len(done)} completions, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
