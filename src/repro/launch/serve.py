"""Serving launcher: batched generation with the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 6 --max-new 16

``--workload cnn`` drives the batched *vision* engine instead (the paper's
own workload): random images through the prepacked bit-serial conv path in
power-of-two micro-batch buckets —

  PYTHONPATH=src python -m repro.launch.serve --workload cnn \
      --cnn-model resnet50 --image 64 --requests 16 --precision '<8:8>'

Multi-device serving maps the paper's chip→bank hierarchy onto a
("data", "model") mesh (DESIGN.md §5/§6): ``--model-par N`` puts N-way
tensor/bank parallelism on the "model" axis and shards the decode-slot
grid (LM) or the image micro-batch (CNN) across the rest of the devices on
"data". On a CPU-only box, force a multi-device host *before any jax
import* (XLA reads the flag at backend init):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --model-par 2 --max-batch 8

With a single device (and the default ``--model-par 1``) the engines run
exactly as before — mesh-free.

``--gateway`` puts the asyncio overload gateway (DESIGN.md §8) in front of
the LM engine: Poisson arrivals at ``--rate`` req/s into bounded per-tenant
queues (``--queue-depth``), per-request deadlines (``--deadline-ms``), load
shedding with retry-after hints, and a final telemetry snapshot —

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --gateway --requests 16 --rate 50 --deadline-ms 2000 --queue-depth 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_serve_mesh
from repro.models.lm import init as model_init
from repro.models.lm.model import cast_params
from repro.serving import Request, SamplerConfig, ServeEngine
from repro.serving.vision import MODEL_ZOO

CNN_MODELS = tuple(sorted(MODEL_ZOO))


def serve_cnn(args, mesh):
    """Vision workload: micro-batched CNN inference (DESIGN.md §6)."""
    from repro.serving import VisionEngine, VisionRequest

    module = MODEL_ZOO[args.cnn_model]
    params = module.init(jax.random.PRNGKey(0), image=args.image,
                         num_classes=args.classes)
    eng = VisionEngine({args.cnn_model: params}, backend=args.backend,
                       max_batch=args.max_batch, mesh=mesh,
                       autotune=args.autotune,
                       tuning_cache=args.tuning_cache)
    rng = np.random.default_rng(0)
    imgs = rng.standard_normal(
        (args.requests, args.image, args.image, 3)).astype(np.float32)
    precision = None if args.precision in ("float", "fp32") else args.precision
    # Warm run populates the prepack + compile caches; the timed run then
    # measures the serving path, not deployment cost.
    for rid in range(args.requests):
        eng.submit(VisionRequest(rid=rid, image=imgs[rid],
                                 model=args.cnn_model, precision=precision))
    eng.run()
    for rid in range(args.requests):
        eng.submit(VisionRequest(rid=rid, image=imgs[rid],
                                 model=args.cnn_model, precision=precision))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    for c in sorted(done, key=lambda c: c.rid)[:8]:
        print(f"req {c.rid}: top1={c.top1} (bucket {c.batch})")
    print(f"{len(done)} images in {dt:.2f}s ({len(done) / dt:.1f} img/s, "
          f"model={args.cnn_model}@{args.image}px, "
          f"precision={args.precision}, backend={args.backend})")


def serve_gateway(args, mesh, cfg, params):
    """``--gateway``: drive the LM engine through the asyncio gateway
    (DESIGN.md §8) with Poisson arrivals, deadlines, bounded per-tenant
    queues, and a final telemetry snapshot."""
    import asyncio

    from repro.serving import (DeadlineExceeded, Gateway, GatewayConfig,
                               ShedError)

    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      max_len=args.max_len,
                      sampler=SamplerConfig(temperature=args.temperature),
                      mesh=mesh, autotune=args.autotune,
                      tuning_cache=args.tuning_cache,
                      pipeline_stages=args.pipeline_stages,
                      pipeline_microbatches=args.pipeline_microbatches)
    gw_cfg = GatewayConfig(queue_depth=args.queue_depth,
                           default_deadline_ms=args.deadline_ms)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(4, 17)))
               .astype(np.int32) for _ in range(args.requests)]
    # Warm run populates the prefill/decode compile caches so deadlines
    # measure serving, not XLA compilation.
    for rid, p in enumerate(prompts[:args.max_batch]):
        eng.submit(Request(rid=rid, prompt=p,
                           max_new_tokens=args.max_new))
    eng.run()

    async def run():
        gw = Gateway(lm=eng, cfg=gw_cfg)
        gw.start()
        done = shed = expired = n_tok = 0

        async def eat(rid, stream):
            nonlocal done, expired, n_tok
            try:
                toks = await stream.result()
                done += 1
                n_tok += len(toks)
                print(f"req {rid}: {len(toks)} tokens -> {toks[:8]}...")
            except DeadlineExceeded:
                expired += 1
                print(f"req {rid}: deadline exceeded "
                      f"({len(stream.tokens)} tokens streamed)")

        tasks = []
        t0 = time.time()
        for rid, p in enumerate(prompts):
            if args.rate > 0:
                await asyncio.sleep(float(rng.exponential(1.0 / args.rate)))
            try:
                s = await gw.submit_lm(p, max_new_tokens=args.max_new,
                                       tenant=f"t{rid % 2}", rid=rid)
                tasks.append(asyncio.ensure_future(eat(rid, s)))
            except ShedError as e:
                shed += 1
                print(f"req {rid}: shed ({e.reason}), "
                      f"retry after {e.retry_after_s:.3f}s")
        await asyncio.gather(*tasks)
        await gw.drain(timeout=120)
        dt = time.time() - t0
        st = gw.stats()
        gw.stop()
        print(f"{done} completions ({n_tok} tokens), {shed} shed, "
              f"{expired} expired in {dt:.1f}s ({n_tok / dt:.1f} tok/s)")
        print(f"gateway: tier={st['tier']} "
              f"ttft_p95={st['ttft_ms']['p95']} ms "
              f"tpot_p95={st['tpot_ms']['p95']} ms "
              f"max_depth={st['queue']['max_depth']}/{st['queue']['bound']} "
              f"shed_rate={st['shed_rate']:.3f}")

    asyncio.run(run())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "cnn"), default="lm")
    ap.add_argument("--gateway", action="store_true",
                    help="serve through the asyncio overload gateway "
                    "(bounded queues, deadlines, shedding; LM workload)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="gateway Poisson arrival rate in req/s "
                    "(0 = submit everything at once)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="gateway per-request deadline")
    ap.add_argument("--queue-depth", type=int, default=32,
                    help="gateway bounded per-tenant queue depth")
    ap.add_argument("--arch", choices=ARCH_IDS,
                    help="LM architecture (required for --workload lm)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--model-par", type=int, default=1,
                    help="devices per model replica (the mesh's 'model' "
                    "axis); the rest shard decode slots / image batches "
                    "on 'data'")
    ap.add_argument("--pipeline-stages", type=int, default=1,
                    help="pipeline the scanned layer stack over N devices "
                    "on a ('stage',) mesh (GPipe fill-drain decode; "
                    "DESIGN.md §11). Mutually exclusive with --model-par")
    ap.add_argument("--pipeline-microbatches", type=int, default=None,
                    help="microbatches streamed through the pipe per decode "
                    "step (default: --pipeline-stages)")
    # --workload cnn
    ap.add_argument("--cnn-model", choices=CNN_MODELS, default="resnet50")
    ap.add_argument("--image", type=int, default=64)
    ap.add_argument("--classes", type=int, default=1000)
    ap.add_argument("--precision", default="<8:8>",
                    help="'<W:I>' bit-widths, or 'float' for the fp path")
    ap.add_argument("--backend", default="int-direct",
                    choices=("int-direct", "popcount", "mxu-plane", "pallas"))
    ap.add_argument("--autotune", default="off",
                    choices=("off", "cost", "measure"),
                    help="per-weight backend/tile autotuning at prepack "
                         "(repro.pim.autotune): 'cost' ranks candidates with "
                         "the NAND-SPIN cost model, 'measure' refines the "
                         "finalists by wall clock")
    ap.add_argument("--tuning-cache", default=None, metavar="PATH",
                    help="JSON tuning-cache file persisting autotune "
                         "decisions across launches (default: in-memory)")
    args = ap.parse_args()

    mesh = None
    if args.pipeline_stages > 1:
        if args.model_par > 1:
            raise SystemExit("--pipeline-stages and --model-par are "
                             "alternative decode compositions; pick one")
        print(f"pipelined decode over {args.pipeline_stages} stage(s), "
              f"{args.pipeline_microbatches or args.pipeline_stages} "
              "microbatch(es)")
    elif len(jax.devices()) > 1 or args.model_par > 1:
        mesh = make_serve_mesh(args.model_par)
        print(f"serving on mesh {dict(mesh.shape)} "
              f"({len(mesh.devices.ravel())} devices)")
    if args.workload == "cnn":
        serve_cnn(args, mesh)
        return
    if args.arch is None:
        raise SystemExit("--workload lm requires --arch")

    arch = get_config(args.arch)
    cfg = arch.model.reduced() if args.reduced else arch.model
    if not cfg.embed_inputs or cfg.cross_attn_every:
        raise SystemExit("serve launcher drives token-in archs; "
                         "musicgen/vlm need frontend-stub drivers (see examples)")
    params = cast_params(model_init(cfg, jax.random.PRNGKey(0)),
                         jnp.dtype(cfg.dtype))
    if args.gateway:
        serve_gateway(args, mesh, cfg, params)
        return
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      max_len=args.max_len,
                      sampler=SamplerConfig(temperature=args.temperature),
                      mesh=mesh, autotune=args.autotune,
                      tuning_cache=args.tuning_cache,
                      pipeline_stages=args.pipeline_stages,
                      pipeline_microbatches=args.pipeline_microbatches)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        L = int(rng.integers(4, 17))
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab, size=L).astype(np.int32), max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(c.tokens) for c in done)
    for c in sorted(done, key=lambda c: c.rid):
        print(f"req {c.rid}: {len(c.tokens)} tokens -> {c.tokens[:8]}...")
    print(f"{len(done)} completions, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
