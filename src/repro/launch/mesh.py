"""Production mesh builders.

Single pod: 16x16 = 256 chips ("data" x "model"). Multi-pod: 2x16x16 = 512
chips ("pod" x "data" x "model") — the pod axis is pure data parallelism
(cross-pod all-reduce rides DCN/ICI), data is FSDP, model is tensor
parallelism.

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


import math


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devices)}; "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = n_devices or len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_serve_mesh(model_par: int = 1, n_devices: int | None = None):
    """Serving mesh ("data", "model") — the paper's chips × banks.

    ``model_par`` devices per model replica (tensor/bank parallelism: the
    "model" axis splits every projection's output columns and the
    PackedWeight planes); the remaining ``n // model_par`` devices shard the
    continuous-batching slot grid (the "data" axis — the paper's chips).
    ``ServeEngine(..., mesh=make_serve_mesh(...))`` does the rest
    (DESIGN.md §5).

    CPU-only boxes: force a multi-device host *before any jax import* —

        XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
            python -m repro.launch.serve --arch qwen3-0.6b --reduced \\
            --model-par 2
    """
    devices = jax.devices()
    n = n_devices or len(devices)
    if n > len(devices):
        raise RuntimeError(
            f"need {n} devices, found {len(devices)}; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N before any "
            "jax import")
    if model_par < 1 or n % model_par:
        raise ValueError(f"model_par={model_par} must divide n_devices={n}")
    return jax.make_mesh((n // model_par, model_par), ("data", "model"),
                         devices=devices[:n])
