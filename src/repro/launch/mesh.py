"""Production mesh builders.

Single pod: 16x16 = 256 chips ("data" x "model"). Multi-pod: 2x16x16 = 512
chips ("pod" x "data" x "model") — the pod axis is pure data parallelism
(cross-pod all-reduce rides DCN/ICI), data is FSDP, model is tensor
parallelism.

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


import math


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devices)}; "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = n_devices or len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
