"""Text-based HLO cost model with while-loop trip-count multiplication.

``compiled.cost_analysis()`` visits each while-loop body ONCE, so any cost
inside ``jax.lax.scan`` (layer stacks, grad-accumulation microbatches,
chunked losses) is undercounted by the trip count — for a 64-layer scanned
model that is a 64x error in every roofline term. This walker parses
``compiled.as_text()`` (post-optimization, post-SPMD, so shapes are
per-shard) and evaluates:

  flops        2 * prod(result) * prod(contracting dims) per dot;
               elementwise/reduce counted at one flop per output element
  hbm bytes    per top-level op: operand + result bytes (fusion internals
               excluded — a fusion reads its params and writes its root,
               which is exactly XLA's fusion memory semantics)
  collectives  bytes per kind, with ring factors and replica-group sizes
               (see repro.roofline.analysis), multiplied through loops

While bodies multiply by ``known_trip_count`` from backend_config (XLA
always annotates scan-derived loops; unknown loops count once and are
reported in ``unknown_loops``). ``conditional`` takes the max over
branches.
"""
from __future__ import annotations

import dataclasses
import math
import re

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \(.*\)\s*->.*\{\s*$")
_INST_PREFIX = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE = re.compile(r"^([\w\-]+)\((.*)$")


def _parse_inst_line(line: str):
    """'  %n = <shape> <op>(<rest>' -> (name, shape, opcode, rest) | None.

    Tuple result shapes may contain '/*index=k*/' comments and nested
    parens, so the shape is split off by paren balancing, not regex.
    """
    m = _INST_PREFIX.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    if rest.startswith("("):           # tuple shape: find matching paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape = rest[: i + 1]
                    tail = rest[i + 1:].lstrip()
                    break
        else:
            return None
    else:                              # plain shape token
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, tail = rest[:sp], rest[sp + 1:].lstrip()
    mo = _OPCODE.match(tail)
    if not mo:
        return None
    return name, shape, mo.group(1), mo.group(2)
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r'known_trip_count\D*?(\d+)')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_ND = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SRC_TGT = re.compile(r"source_target_pairs=")
_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

# Ops that move no data of their own.
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "iota", "partition-id", "replica-id", "domain",
         "opt-barrier"}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_elems_bytes(shape_str: str) -> tuple:
    elems = 0
    total = 0
    for dtype, dims in _SHAPE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


def _dims_of(shape_str: str) -> list:
    m = _SHAPE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLLECTIVE_KINDS})
    unknown_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.wire_bytes += other.wire_bytes * mult
        for k in COLLECTIVE_KINDS:
            self.coll_bytes[k] += other.coll_bytes[k] * mult
            self.coll_counts[k] += int(other.coll_counts[k] * mult)
        self.unknown_loops += other.unknown_loops


@dataclasses.dataclass
class Instruction:
    name: str
    shape: str
    opcode: str
    rest: str  # operand list + attributes (raw tail of the line)

    def operand_names(self) -> list:
        # operands come first in `rest`, up to the closing paren at depth 0
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    head = self.rest[:i]
                    return re.findall(r"%([\w.\-]+)", head)
        return re.findall(r"%([\w.\-]+)", self.rest)


def parse_module(text: str) -> dict:
    comps: dict = {}
    cur = None
    entry = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        parsed = _parse_inst_line(line)
        if parsed:
            comps[cur].append(Instruction(*parsed))
    return {"computations": comps, "entry": entry}


def _group_size(rest: str) -> int:
    m = _GROUPS_ND.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS.search(rest)
    if m:
        return m.group(1).count(",") + 1
    if _SRC_TGT.search(rest):
        return 2
    return 1


class CostModel:
    def __init__(self, text: str):
        mod = parse_module(text)
        self.comps = mod["computations"]
        self.entry = mod["entry"]
        self._memo: dict = {}

    def evaluate(self) -> Cost:
        return self._comp_cost(self.entry, top_level=True)

    # -- internals ----------------------------------------------------------

    def _comp_cost(self, name: str, top_level: bool) -> Cost:
        key = (name, top_level)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        shapes = {i.name: i.shape for i in self.comps.get(name, [])}
        for inst in self.comps.get(name, []):
            total.add(self._inst_cost(inst, shapes, top_level))
        self._memo[key] = total
        return total

    def _flops_only(self, name: str) -> float:
        """Flops of a fusion body (bytes are the fusion's own I/O)."""
        total = 0.0
        shapes = {i.name: i.shape for i in self.comps.get(name, [])}
        for inst in self.comps.get(name, []):
            if inst.opcode == "fusion":
                m = _CALLS.search(inst.rest)
                if m:
                    total += self._flops_only(m.group(1))
            else:
                total += self._op_flops(inst, shapes)
        return total

    def _op_flops(self, inst: Instruction, shapes: dict) -> float:
        op = inst.opcode
        out_elems, _ = _shape_elems_bytes(inst.shape)
        if op == "dot":
            cd = _CDIMS.search(inst.rest)
            contract = 1
            ops = inst.operand_names()
            if cd and ops and ops[0] in shapes:
                lhs_dims = _dims_of(shapes[ops[0]])
                for d in cd.group(1).split(","):
                    if d and int(d) < len(lhs_dims):
                        contract *= lhs_dims[int(d)]
            return 2.0 * out_elems * contract
        if op == "convolution":
            # flops ~ 2 * out_elems * (kernel elems / out-channels)
            ops = inst.operand_names()
            if len(ops) >= 2 and ops[1] in shapes:
                kdims = _dims_of(shapes[ops[1]])
                if kdims:
                    return 2.0 * out_elems * max(1, math.prod(kdims) // max(kdims[-1], 1))
            return 2.0 * out_elems
        if op in ("reduce", "reduce-window"):
            ops = inst.operand_names()
            in_elems = 0
            if ops and ops[0] in shapes:
                in_elems, _ = _shape_elems_bytes(shapes[ops[0]])
            return float(max(in_elems, out_elems))
        if op in _FREE or op in ("copy", "reshape", "transpose", "broadcast",
                                 "dynamic-slice", "dynamic-update-slice",
                                 "slice", "concatenate", "gather", "scatter",
                                 "pad", "reverse", "while", "conditional",
                                 "call", "custom-call", "rng", "sort") or \
           op in COLLECTIVE_KINDS or op.endswith("-start") or op.endswith("-done"):
            return 0.0
        # default: one flop per output element (elementwise / compare / select)
        return float(out_elems)

    def _inst_cost(self, inst: Instruction, shapes: dict, top_level: bool) -> Cost:
        c = Cost()
        op = inst.opcode
        base_kind = op[:-6] if op.endswith("-start") else op

        if op == "fusion":
            m = _CALLS.search(inst.rest)
            if m:
                c.flops += self._flops_only(m.group(1))
                c.bytes += self._fusion_bytes(inst, shapes, m.group(1))
            else:
                c.bytes += self._io_bytes(inst, shapes)
            return c
        if op == "while":
            trips = 1
            mt = _TRIP.search(inst.rest)
            if mt:
                trips = int(mt.group(1))
            else:
                c.unknown_loops += 1
            mb = _BODY.search(inst.rest)
            mc = _COND.search(inst.rest)
            if mb:
                c.add(self._comp_cost(mb.group(1), top_level=True), trips)
            if mc:
                c.add(self._comp_cost(mc.group(1), top_level=True), trips)
            return c
        if op == "conditional":
            mb = _BRANCHES.search(inst.rest)
            if mb:
                branches = re.findall(r"%?([\w.\-]+)", mb.group(1))
                costs = [self._comp_cost(b, top_level=True) for b in branches]
                if costs:
                    best = max(costs, key=lambda x: x.flops + x.bytes)
                    c.add(best)
            return c
        if op == "call":
            m = _CALLS.search(inst.rest) or re.search(r"to_apply=%?([\w.\-]+)",
                                                      inst.rest)
            if m:
                c.add(self._comp_cost(m.group(1), top_level=True))
            return c
        if base_kind in COLLECTIVE_KINDS:
            if op.endswith("-done"):
                return c
            n = _group_size(inst.rest)
            b = self._io_bytes(inst, shapes, result_only_max=True)
            c.bytes += self._io_bytes(inst, shapes)
            if n > 1:
                ring = (n - 1) / n
                c.coll_counts[base_kind] += 1
                c.coll_bytes[base_kind] += b
                if base_kind == "all-reduce":
                    c.wire_bytes += 2 * b * ring
                elif base_kind == "collective-permute":
                    c.wire_bytes += b
                else:
                    c.wire_bytes += b * ring
            return c
        if op in _FREE:
            return c
        if op in ("dynamic-update-slice", "scatter"):
            # In-place semantics (XLA HloCostAnalysis counts the update
            # slice, not the whole buffer): read+write the update only.
            ops_ = inst.operand_names()
            upd = ops_[1] if len(ops_) > 1 else None
            _, ub = _shape_elems_bytes(shapes.get(upd, "")) if upd else (0, 0)
            c.bytes += 2.0 * ub
            return c
        if op in ("dynamic-slice", "gather", "slice"):
            # Reads only the addressed window, writes the result.
            _, rb = _shape_elems_bytes(inst.shape)
            c.bytes += 2.0 * rb
            return c
        # plain top-level op
        c.flops += self._op_flops(inst, shapes)
        c.bytes += self._io_bytes(inst, shapes)
        return c

    def _fusion_bytes(self, inst: Instruction, shapes: dict, called: str) -> float:
        """Fusion I/O with in-place DUS-root correction.

        A loop fusion whose root is dynamic-update-slice updates its big
        operand in place: traffic is the update slice (+ the other fusion
        inputs), not 2x the whole carried buffer."""
        total = self._io_bytes(inst, shapes)
        body = self.comps.get(called, [])
        if not body:
            return total
        inner_shapes = {i.name: i.shape for i in body}
        root = body[-1]
        if root.opcode == "bitcast" and root.operand_names():
            src = root.operand_names()[0]
            root = next((i for i in body if i.name == src), root)
        if root.opcode == "dynamic-update-slice":
            _, big = _shape_elems_bytes(root.shape)
            ops_ = root.operand_names()
            upd = ops_[1] if len(ops_) > 1 else None
            _, ub = _shape_elems_bytes(inner_shapes.get(upd, "")) if upd else (0, 0)
            # remove buffer read + buffer write, add update read + write
            total = max(0.0, total - 2.0 * big + 2.0 * ub)
        # Fusion params consumed ONLY by dynamic-slice read just the window
        # (scan xs unstacking): count slice sizes, not the stacked buffer.
        for p in body:
            if p.opcode != "parameter":
                continue
            uses = [i for i in body if p.name in i.operand_names()
                    and i.opcode != "parameter"]
            if uses and all(u.opcode == "dynamic-slice" for u in uses):
                _, full = _shape_elems_bytes(p.shape)
                sliced = sum(_shape_elems_bytes(u.shape)[1] for u in uses)
                total = max(0.0, total - full + sliced)
        return total

    def _io_bytes(self, inst: Instruction, shapes: dict,
                  result_only_max: bool = False) -> float:
        _, out_b = _shape_elems_bytes(inst.shape)
        in_b = 0
        for o in inst.operand_names():
            if o in shapes:
                _, b = _shape_elems_bytes(shapes[o])
                in_b += b
        if result_only_max:
            return float(max(out_b, in_b))
        return float(out_b + in_b)


def analyze(text: str) -> Cost:
    return CostModel(text).evaluate()
