"""Target-hardware constants: TPU v5e (the dry-run's compile target).

Numbers from the assignment brief; the roofline terms in
:mod:`repro.roofline.analysis` are computed against these.
"""
PEAK_FLOPS_BF16 = 197e12     # FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (effective, one direction)
HBM_BYTES = 16 * 1024**3     # v5e HBM capacity per chip
VMEM_BYTES = 128 * 1024**2
