"""Per-op-kind cost breakdown over an HLO module — the dry-run 'profiler'.

With no hardware to trace, the optimization loop's profile is: which
opcodes (weighted by loop trip counts) account for the bytes/flops. Used by
the §Perf iterations to decide what to attack next.
"""
from __future__ import annotations

import collections

from . import hlo_cost


class BreakdownModel(hlo_cost.CostModel):
    def __init__(self, text: str):
        super().__init__(text)
        self.by_op_bytes: dict = collections.Counter()
        self.by_op_flops: dict = collections.Counter()

    def evaluate_with_breakdown(self):
        total = self._comp_cost_bd(self.entry, 1.0)
        return total, dict(self.by_op_bytes), dict(self.by_op_flops)

    def _comp_cost_bd(self, name: str, mult: float) -> hlo_cost.Cost:
        total = hlo_cost.Cost()
        shapes = {i.name: i.shape for i in self.comps.get(name, [])}
        for inst in self.comps.get(name, []):
            op = inst.opcode
            if op == "while":
                trips = 1
                mt = hlo_cost._TRIP.search(inst.rest)
                if mt:
                    trips = int(mt.group(1))
                mb = hlo_cost._BODY.search(inst.rest)
                if mb:
                    total.add(self._comp_cost_bd(mb.group(1), mult * trips), trips)
                continue
            if op == "call":
                m = hlo_cost._CALLS.search(inst.rest)
                if m:
                    total.add(self._comp_cost_bd(m.group(1), mult))
                continue
            c = self._inst_cost(inst, shapes, True)
            total.add(c)
            key = op if op != "fusion" else "fusion"
            self.by_op_bytes[key] += c.bytes * mult
            self.by_op_flops[key] += c.flops * mult
        return total


def breakdown(text: str, top: int = 12):
    m = BreakdownModel(text)
    total, by_bytes, by_flops = m.evaluate_with_breakdown()
    rows = []
    for op, b in sorted(by_bytes.items(), key=lambda kv: -kv[1])[:top]:
        rows.append({"op": op, "GB": round(b / 1e9, 1),
                     "bytes_frac": round(b / max(total.bytes, 1), 3),
                     "GFLOP": round(by_flops.get(op, 0) / 1e9, 1)})
    return total, rows


def attribute(text: str, metric: str = "wire", top: int = 16):
    """Attribute a cost metric ('wire' | 'bytes' | 'flops') to
    (opcode, jax op_name) sites, with loop-trip multiplication."""
    import re

    cm = hlo_cost.CostModel(text)
    meta_re = re.compile(r'op_name="([^"]*)"')
    agg: dict = collections.Counter()
    cnt: dict = collections.Counter()

    def walk(comp, mult):
        shapes = {i.name: i.shape for i in cm.comps.get(comp, [])}
        for inst in cm.comps.get(comp, []):
            op = inst.opcode
            if op == "while":
                mt = hlo_cost._TRIP.search(inst.rest)
                trips = int(mt.group(1)) if mt else 1
                mb = hlo_cost._BODY.search(inst.rest)
                if mb:
                    walk(mb.group(1), mult * trips)
                continue
            if op == "call":
                m = hlo_cost._CALLS.search(inst.rest)
                if m:
                    walk(m.group(1), mult)
                continue
            c = cm._inst_cost(inst, shapes, True)
            val = {"wire": c.wire_bytes, "bytes": c.bytes, "flops": c.flops}[metric]
            if val:
                m = meta_re.search(inst.rest)
                parts = [p for p in (m.group(1) if m else "?").split("/") if p]
                key = (op.split("-start")[0], "/".join(parts[-2:])[:70])
                agg[key] += val * mult
                cnt[key] += mult

    walk(cm.entry, 1.0)
    rows = []
    for (op, name), v in agg.most_common(top):
        rows.append({"value_T": round(v / 1e12, 3), "n": int(cnt[(op, name)]),
                     "op": op, "site": name})
    return rows


def _main():
    import argparse
    import gzip

    ap = argparse.ArgumentParser()
    ap.add_argument("hlo", help="path to .hlo.txt[.gz]")
    ap.add_argument("--metric", choices=("wire", "bytes", "flops"),
                    default="wire")
    ap.add_argument("--top", type=int, default=16)
    args = ap.parse_args()
    opener = gzip.open if args.hlo.endswith(".gz") else open
    with opener(args.hlo, "rt") as f:
        text = f.read()
    for r in attribute(text, args.metric, args.top):
        print(f"{r['value_T']:9.3f} T{args.metric[0].upper()}  n={r['n']:6d}  "
              f"{r['op']:20s} {r['site']}")


if __name__ == "__main__":
    _main()
