"""Roofline terms from a compiled (dry-run) executable.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective bytes on the wire / link_bw  (per chip)

``cost_analysis()`` supplies FLOPs and bytes. Collective bytes are NOT in
cost_analysis — we parse the post-partitioning HLO (``compiled.as_text()``,
whose shapes are already per-shard) and sum the bytes each collective moves
per chip, with ring-algorithm factors:

  all-reduce      2 x bytes x (n-1)/n     (reduce-scatter + all-gather)
  all-gather      result_bytes x (n-1)/n
  reduce-scatter  operand_bytes x (n-1)/n
  all-to-all      bytes x (n-1)/n
  collective-permute  bytes

where n is the size of the replica group the op runs over (parsed from
``replica_groups``; n=1 groups contribute nothing).
"""
from __future__ import annotations

import dataclasses
import re

from . import hw

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# op name at the assignment site, e.g. "%ag = bf16[..] all-gather(..)"
_OP_RE = re.compile(
    r"=\s*((?:\([^=]*?\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ND_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{(.*?)\}")


def shape_bytes(shape_str: str) -> int:
    """'bf16[8,128,256]' or tuple '(f32[4], f32[4])' -> total bytes."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_ND_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _SRC_TGT_RE.search(line)
    if m:  # collective-permute: each chip sends once
        return 2
    return 1


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    wire_bytes: float        # per-chip bytes on the wire (ring factors applied)
    op_counts: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    by_kind: dict = {k: 0.0 for k in _COLLECTIVES}
    counts: dict = {k: 0 for k in _COLLECTIVES}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":   # bytes counted at the -start site
            continue
        n = _group_size(line)
        if n <= 1:
            continue
        b = shape_bytes(shape_str)
        counts[kind] += 1
        by_kind[kind] += b
        ring = (n - 1) / n
        if kind == "all-reduce":
            wire += 2 * b * ring
        elif kind == "collective-permute":
            wire += b
        else:
            wire += b * ring
    return CollectiveStats(bytes_by_kind=by_kind, wire_bytes=wire, op_counts=counts)


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    chips: int
    model_flops: float = 0.0   # 6*N*D (or 6*N_active*D) useful FLOPs, global

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_chip / hw.ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Step-time lower bound = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global) — catches remat/redundancy waste."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute roofline fraction at the bound: what MFU would be
        if the step ran exactly at the dominant term."""
        if not self.model_flops or not self.t_bound:
            return 0.0
        per_chip_useful = self.model_flops / self.chips
        return (per_chip_useful / hw.PEAK_FLOPS_BF16) / self.t_bound

    def report(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def train_model_flops(n_params: int, n_tokens: int) -> float:
    """6*N*D for a train step (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params * n_tokens


def decode_model_flops(n_params: int, n_tokens: int) -> float:
    """2*N per generated token (no backward)."""
    return 2.0 * n_params * n_tokens


def from_compiled(compiled, chips: int, model_flops: float) -> Roofline:
    """Roofline terms from the compiled SPMD module (per-shard shapes).

    Primary source: the trip-count-aware HLO walker in
    :mod:`repro.roofline.hlo_cost` — XLA's own ``cost_analysis()`` counts
    scan bodies once, which undercounts deep models by ~n_layers x.
    """
    from . import hlo_cost

    cost = hlo_cost.analyze(compiled.as_text())
    return Roofline(
        flops_per_chip=cost.flops,
        hbm_bytes_per_chip=cost.bytes,
        wire_bytes_per_chip=cost.wire_bytes,
        chips=chips,
        model_flops=model_flops,
    )
