from . import analysis, hw

__all__ = ["analysis", "hw"]
