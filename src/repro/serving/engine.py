"""Batched serving engine: continuous batching over a fixed decode grid.

The engine owns one device-resident decode state of shape
``(max_batch, max_len)`` and runs two jitted programs:

  * ``prefill_one`` — runs a prompt through the model into slot ``i`` of
    the batch (per-slot KV insertion via dynamic updates), padded to the
    next power-of-two prompt bucket to bound recompilation;
  * ``decode_all``  — one token for every live slot per call (the decode
    grid never reshapes; dead slots decode into a trash position).

Continuous batching: when a sequence finishes (EOS or budget), its slot is
released and the next queued request prefills into it — the decode grid
keeps running; there is no global drain. This is the vLLM-style admission
scheme restricted to a static grid, which is what a fixed-shape compiled
TPU program wants.

Fault tolerance: the engine state is a pytree; ``snapshot``/``restore``
round-trips it through the checkpoint module, so a preempted server resumes
mid-generation.

PIM deployment: when ``cfg.pim`` is enabled the constructor prepacks every
projection weight into :class:`repro.core.packed.PackedWeight` — the
paper's program-subarrays-once step — so prefill/decode never re-calibrate,
re-quantize or re-pack a weight (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import decode_step, init_state, prefill, prepack_params
from repro.models.lm.config import ModelConfig

from .sampler import SamplerConfig, sample


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                # -1: never


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_len: int = 512, sampler: SamplerConfig | None = None):
        self.cfg = cfg
        # Deployment-time weight quantize+pack, exactly once (the paper
        # programs subarrays once): every prefill/decode after this reuses
        # the PackedWeight planes — no per-call re-calibration or re-pack.
        self.params = prepack_params(params, cfg.pim)
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampler = sampler or SamplerConfig()
        self.state = init_state(cfg, max_batch, max_len)
        # Per-slot host bookkeeping.
        self.slot_req: list = [None] * max_batch
        self.slot_remaining = np.zeros(max_batch, np.int32)
        self.slot_last_tok = np.zeros(max_batch, np.int32)
        self.queue: list = []
        self.done: list = []
        self.slot_pos = np.zeros(max_batch, np.int32)  # per-slot position

        self._decode = jax.jit(partial(self._decode_impl, cfg))

    # -- jitted bodies ------------------------------------------------------

    @staticmethod
    def _decode_impl(cfg, params, tokens, state):
        logits, new_state = decode_step(params, cfg, tokens, state)
        return logits, new_state

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        """Prefill queued requests into free slots (simple per-slot loop)."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            L = len(req.prompt)
            tokens = jnp.asarray(req.prompt, jnp.int32)[None]
            # Single-sequence prefill at batch=1, then graft into the grid.
            s1 = init_state(self.cfg, 1, self.max_len)
            logits, s1 = prefill(self.params, self.cfg, tokens, s1)
            self._graft(s1, slot, L)
            nxt = int(sample(logits[:, -1], self.sampler,
                             jax.random.PRNGKey(req.rid))[0])
            self.slot_req[slot] = req
            self.slot_remaining[slot] = req.max_new_tokens - 1
            self.slot_last_tok[slot] = nxt
            self.slot_pos[slot] = L

    def _graft(self, s1, slot: int, length: int):
        """Copy batch-0 of a fresh prefill state into slot ``slot``.

        Scan-position states carry a leading (n_reps,) axis; rest states
        have batch leading — handled uniformly by shape inspection."""
        def graft_leaf(big, small):
            # The batch axis is wherever the fresh (batch=1) prefill state
            # has extent 1 and the grid has extent max_batch — axis 0 for
            # rest states, axis 1 for scan-stacked (reps leading).
            for ax in range(min(big.ndim, 2)):
                if big.shape[ax] == self.max_batch and small.shape[ax] == 1:
                    idx = (slice(None),) * ax + (slot,)
                    src = (slice(None),) * ax + (0,)
                    return big.at[idx].set(small[src])
            return big

        new_scan = [jax.tree.map(graft_leaf, bl, sl)
                    for bl, sl in zip(self.state["scan"], s1["scan"])]
        new_rest = [jax.tree.map(graft_leaf, bl, sl)
                    for bl, sl in zip(self.state["rest"], s1["rest"])]
        self.state = dict(self.state, scan=new_scan, rest=new_rest)

    def step(self) -> list:
        """Admit + one decode step for all live slots; returns completions."""
        self._admit()
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return self._drain_done()
        toks = jnp.asarray(self.slot_last_tok, jnp.int32)[:, None]
        # Per-slot positions: each live slot decodes at its own offset.
        self.state["length"] = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.state = self._decode(self.params, toks, self.state)
        nxt = np.asarray(sample(logits[:, 0], self.sampler, jax.random.PRNGKey(
            int(self.slot_pos.sum()))))
        for i in live:
            req = self.slot_req[i]
            tok = int(nxt[i])
            if not hasattr(req, "_out"):
                req._out = [int(self.slot_last_tok[i])]
            req._out.append(tok)
            self.slot_last_tok[i] = tok
            self.slot_pos[i] += 1
            self.slot_remaining[i] -= 1
            if tok == req.eos_id or self.slot_remaining[i] <= 0:
                self.done.append(Completion(req.rid, req._out))
                self.slot_req[i] = None
        return self._drain_done()

    def _drain_done(self):
        out, self.done = self.done, []
        return out

    def run(self, max_steps: int = 10_000) -> list:
        """Drive until queue + slots drain; returns all completions."""
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.queue and all(r is None for r in self.slot_req):
                break
        return out
