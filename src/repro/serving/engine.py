"""Batched serving engine: continuous batching over a fixed decode grid.

The engine owns one device-resident decode state of shape
``(max_batch, max_len)`` plus a device-resident per-slot control block
(last token, eos id, remaining budget, live flag, PRNG key) and runs three
jitted programs, all with **buffer donation** so XLA updates the KV /
recurrent state in place instead of allocating a copy per call:

  * ``prefill_into_slot`` — admission path. The prompt is split into its
    binary decomposition of power-of-two chunks (13 -> 8 + 4 + 1) and each
    chunk prefills into slot ``i`` via ``dynamic_update_slice`` under jit;
    chunk lengths are the only shape that varies, so a varied-length
    workload compiles at most ceil(log2(max_len)) prefill variants.
    Chunking (instead of right-padding to a bucket) keeps recurrent
    (RG-LRU / RWKV) and ring-buffer states exact: carry state threads
    across chunks and no pad token ever enters the recurrence.
  * ``decode_n`` — steady state. A ``jax.lax.scan`` runs up to
    ``drain_steps`` decode steps per dispatch when no admissions are
    pending; **sampling is fused into the jitted step** (one engine key
    split per step, then per slot), so only the (n, B) sampled tokens and
    done flags cross to host — never the (B, vocab) logits. Dead slots
    decode into a frozen trash position; the grid never reshapes.
  * ``admit_ctrl`` — writes a freshly-prefilled slot's control entries and
    samples its first token in-jit.

Continuous batching: when a sequence finishes (EOS or budget), its slot is
released and the next queued request prefills into it — the decode grid
keeps running; there is no global drain. While the queue is non-empty the
engine decodes one step at a time so a freed slot is refilled at the next
token boundary; once the queue drains it switches to multi-step dispatches.

Fault tolerance: ``snapshot``/``restore`` round-trip the device state +
control block through the checkpoint module and carry the per-slot host
bookkeeping in the manifest, so a preempted server resumes mid-generation
(queued-but-unadmitted requests are the caller's to resubmit).

PIM deployment: when ``cfg.pim`` is enabled the constructor prepacks every
projection weight into :class:`repro.core.packed.PackedWeight` — the
paper's program-subarrays-once step — so prefill/decode never re-calibrate,
re-quantize or re-pack a weight (DESIGN.md §3/§4).

Mesh-sharded serving (DESIGN.md §5): pass ``mesh`` (a ("data", "model")
mesh, e.g. ``repro.launch.mesh.make_serve_mesh``) and the engine maps the
paper's chip→bank→subarray hierarchy onto it — batch slots (chips) shard
on "data", every projection's output columns and the PackedWeight planes
(banks) on "model", and the bit-serial kernels tile subarrays into VMEM.
All three hot-loop programs compile with explicit in/out shardings equal to
the committed layouts, so under donation the steady-state decode loop never
inserts a resharding transfer — the only collectives are the tensor-parallel
partial-sum all-reduces and KB-scale scatter-index broadcasts (asserted on
compiled HLO in tests/test_serve_sharded.py).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import (
    decode_step, init_state, prefill_into_slot, prepack_params,
)
from repro.models.lm.config import ModelConfig

from .sampler import SamplerConfig, sample_per_slot


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                # -1: never


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list


def _pow2_chunks(n: int) -> list[int]:
    """Binary decomposition, largest first: 13 -> [8, 4, 1]."""
    out = []
    b = 1 << max(n.bit_length() - 1, 0)
    while n:
        if n >= b:
            out.append(b)
            n -= b
        b >>= 1
    return out


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_len: int = 512, sampler: SamplerConfig | None = None,
                 seed: int = 0, drain_steps: int = 8, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        if mesh is not None and getattr(cfg.pim, "enabled", False) \
                and getattr(cfg.pim, "backend", "") == "pallas":
            # pallas_call has no GSPMD partitioning rule: under plain jit the
            # "model"-split planes would silently all-gather every step.
            # (kernels.bitserial_matmul_sharded is the shard_map primitive
            # for mesh-level pallas use; it is not wired into pim_linear yet.)
            raise ValueError(
                "mesh-sharded serving does not support pim backend 'pallas'; "
                "use 'popcount' or 'int-direct' (both partition under GSPMD)")
        # Deployment-time weight quantize+pack, exactly once (the paper
        # programs subarrays once): every prefill/decode after this reuses
        # the PackedWeight planes — no per-call re-calibration or re-pack.
        # With a mesh, the tree is committed to the serving layout here
        # (banks = "model"-axis column split; DESIGN.md §5).
        self.params = prepack_params(params, cfg.pim, mesh=mesh)
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampler = sampler or SamplerConfig()
        self.drain_steps = max(1, drain_steps)
        self.state = init_state(cfg, max_batch, max_len)
        # Device-resident per-slot control block: consumed and produced by
        # the jitted decode under donation, so steady state moves no
        # control data between host and device.
        self.ctrl = {
            "last_tok": jnp.zeros((max_batch,), jnp.int32),
            "eos": jnp.full((max_batch,), -1, jnp.int32),
            "remaining": jnp.zeros((max_batch,), jnp.int32),
            "live": jnp.zeros((max_batch,), bool),
            "key": jax.random.PRNGKey(seed),
        }
        # Host bookkeeping mirrors (admission decisions + output assembly).
        self.slot_req: list = [None] * max_batch
        self.slot_out: list = [[] for _ in range(max_batch)]
        self.slot_remaining = np.zeros(max_batch, np.int32)
        self.queue: collections.deque = collections.deque()
        self.done: list = []

        # With a mesh, every hot-loop program compiles with explicit in/out
        # shardings equal to the committed layouts: the donated state/ctrl
        # buffers then alias in place AND keep one stable layout across
        # calls, so steady-state decode inserts no resharding transfer
        # (asserted on HLO in tests/test_serve_sharded.py).
        pf_kw, ad_kw, self._dec_kw = {}, {}, {}
        if mesh is not None:
            from repro.distributed import sharding as _sh

            p_sh = _sh.serve_param_shardings(self.params, mesh)
            s_sh = _sh.serve_state_shardings(self.state, mesh)
            c_sh = _sh.serve_ctrl_shardings(self.ctrl, mesh)
            repl = _sh.replicated(mesh)
            self.state = jax.device_put(self.state, s_sh)
            self.ctrl = jax.device_put(self.ctrl, c_sh)
            self._shardings = (p_sh, s_sh, c_sh)
            stream = _sh.serve_stream_sharding(mesh, max_batch)
            pf_kw = dict(in_shardings=(p_sh, s_sh, repl, repl, repl),
                         out_shardings=(repl, s_sh))
            ad_kw = dict(in_shardings=(c_sh, repl, repl, repl, repl),
                         out_shardings=(c_sh, repl))
            self._dec_kw = dict(in_shardings=(p_sh, s_sh, c_sh),
                                out_shardings=(s_sh, c_sh, stream, stream))

        self._prefill = jax.jit(partial(self._prefill_impl, cfg),
                                donate_argnums=(1,), **pf_kw)
        self._admit_ctrl = jax.jit(partial(self._admit_impl, self.sampler),
                                   donate_argnums=(0,), **ad_kw)
        self._decode = {}   # scan length -> jitted decode_n program

    @contextlib.contextmanager
    def _activate(self):
        """Scope the engine's mesh to its own program calls.

        The sharding module's mesh is process-global (model code stays
        mesh-agnostic); tracing happens inside the jitted calls, so the
        mesh — and the serving KV layout flag consumed by
        ``constrain_kv_update`` — is activated around each call and
        restored after, instead of leaking into every later trace in the
        process (a mesh-free engine built afterwards must not inherit it).
        Mesh-free engines leave the global state alone entirely."""
        if self.mesh is None:
            yield
            return
        from repro.distributed import sharding as _sh

        prev_mesh, prev_serve = _sh.get_mesh(), _sh.get_serve_layout()
        _sh.set_mesh(self.mesh)
        _sh.set_serve_layout(True)
        try:
            yield
        finally:
            _sh.set_mesh(prev_mesh)
            _sh.set_serve_layout(prev_serve)

    # -- jitted bodies ------------------------------------------------------

    @staticmethod
    def _prefill_impl(cfg, params, state, tokens, slot, start):
        return prefill_into_slot(params, cfg, tokens, state, slot, start)

    @staticmethod
    def _admit_impl(sampler, ctrl, logits, slot, eos_id, n_new):
        """Sample the first token and write slot ``slot``'s control entries."""
        key, sub = jax.random.split(ctrl["key"])
        tok = sample_per_slot(logits[:, -1], sampler, sub[None])[0]
        eos_id = jnp.asarray(eos_id, jnp.int32)
        alive = (jnp.asarray(n_new, jnp.int32) > 1) & (tok != eos_id)

        def put(ref, val):
            return jax.lax.dynamic_update_slice(
                ref, jnp.asarray(val, ref.dtype)[None], (slot,))

        ctrl = dict(
            ctrl, key=key,
            last_tok=put(ctrl["last_tok"], tok),
            eos=put(ctrl["eos"], eos_id),
            remaining=put(ctrl["remaining"], jnp.asarray(n_new, jnp.int32) - 1),
            live=put(ctrl["live"], alive),
        )
        return ctrl, tok

    @staticmethod
    def _step_core(cfg, sampler, params, state, ctrl):
        """One fused decode+sample step. Only (B,) tokens/flags leave jit."""
        logits, new_state = decode_step(params, cfg,
                                        ctrl["last_tok"][:, None], state)
        key, sub = jax.random.split(ctrl["key"])
        keys = jax.random.split(sub, ctrl["last_tok"].shape[0])
        nxt = sample_per_slot(logits[:, 0], sampler, keys)
        nxt = jnp.where(ctrl["live"], nxt, ctrl["last_tok"])
        remaining = ctrl["remaining"] - ctrl["live"].astype(jnp.int32)
        done = ctrl["live"] & ((nxt == ctrl["eos"]) | (remaining <= 0))
        # Dead slots do not advance: their trash KV writes land on one row,
        # which the next occupant overwrites before it becomes attendable.
        new_state["length"] = jnp.where(ctrl["live"], new_state["length"],
                                        state["length"])
        ctrl = dict(ctrl, key=key, last_tok=nxt, remaining=remaining,
                    live=ctrl["live"] & ~done)
        return new_state, ctrl, nxt, done

    @staticmethod
    def _decode_impl(cfg, sampler, n, params, state, ctrl):
        """``n`` fused decode steps per dispatch; emits (n, B) tokens/flags."""
        def body(carry, _):
            st, ct = carry
            st, ct, tok, done = ServeEngine._step_core(cfg, sampler,
                                                       params, st, ct)
            return (st, ct), (tok, done)

        (state, ctrl), (toks, dones) = jax.lax.scan(
            body, (state, ctrl), None, length=n)
        return state, ctrl, toks, dones

    def _decode_fn(self, n: int):
        fn = self._decode.get(n)
        if fn is None:
            fn = jax.jit(partial(self._decode_impl, self.cfg, self.sampler, n),
                         donate_argnums=(1, 2), **self._dec_kw)
            self._decode[n] = fn
        return fn

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        """Prefill queued requests into free slots, chunked power-of-two."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            prompt = np.asarray(req.prompt, np.int32)
            pos, logits = 0, None
            with self._activate():
                for c in _pow2_chunks(len(prompt)):
                    tokens = jnp.asarray(prompt[pos:pos + c], jnp.int32)[None]
                    logits, self.state = self._prefill(
                        self.params, self.state, tokens, slot, pos)
                    pos += c
                self.ctrl, tok = self._admit_ctrl(
                    self.ctrl, logits, slot, req.eos_id, req.max_new_tokens)
            first = int(tok)
            self.slot_out[slot] = [first]
            if req.max_new_tokens <= 1 or first == req.eos_id:
                self.done.append(Completion(req.rid, self.slot_out[slot]))
                continue
            self.slot_req[slot] = req
            self.slot_remaining[slot] = req.max_new_tokens - 1

    def step(self) -> list:
        """Admit + decode (one step, or a drain of up to ``drain_steps``
        fused steps when no admissions are pending); returns completions."""
        self._admit()
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return self._drain_done()
        if self.queue:
            n = 1   # keep admissions responsive: a slot may free next token
        else:
            cap = max(1, min(self.drain_steps,
                             int(max(self.slot_remaining[i] for i in live))))
            n = 1 << (cap.bit_length() - 1)   # pow2 -> bounded compile count
        with self._activate():
            self.state, self.ctrl, toks, dones = self._decode_fn(n)(
                self.params, self.state, self.ctrl)
        toks = np.asarray(toks)
        dones = np.asarray(dones)
        for k in range(n):
            for i in list(live):
                req = self.slot_req[i]
                self.slot_out[i].append(int(toks[k, i]))
                self.slot_remaining[i] -= 1
                if dones[k, i]:
                    self.done.append(Completion(req.rid, self.slot_out[i]))
                    self.slot_req[i] = None
                    live.remove(i)
        return self._drain_done()

    def _drain_done(self):
        out, self.done = self.done, []
        return out

    def run(self, max_steps: int = 10_000) -> list:
        """Drive until queue + slots drain; returns all completions."""
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.queue and all(r is None for r in self.slot_req):
                break
        return out

    # -- fault tolerance ----------------------------------------------------

    def snapshot(self, ckpt_dir: str, step: int = 0):
        """Checkpoint device state + control block + slot bookkeeping.

        Queued-but-unadmitted requests are not saved — resubmit after
        ``restore``. Safe mid-generation: saving copies to host, it does
        not consume the donated device buffers."""
        from repro.training import checkpoint as ckpt

        slots = []
        for i, r in enumerate(self.slot_req):
            slots.append(None if r is None else {
                "rid": r.rid, "prompt": np.asarray(r.prompt).tolist(),
                "max_new_tokens": r.max_new_tokens, "eos_id": r.eos_id,
                "out": list(self.slot_out[i]),
                "remaining": self.slot_remaining[i],
            })
        ckpt.save(ckpt_dir, step, {"state": self.state, "ctrl": self.ctrl},
                  extra={"slots": slots, "max_batch": self.max_batch,
                         "max_len": self.max_len})

    def restore(self, ckpt_dir: str, step: int | None = None):
        """Resume mid-generation from :meth:`snapshot` (same cfg/geometry)."""
        from repro.training import checkpoint as ckpt

        like = {"state": self.state, "ctrl": self.ctrl}
        tree, manifest = ckpt.restore(ckpt_dir, like, step=step)
        if self.mesh is not None:
            # Commit straight to the canonical serving layout — the hot-loop
            # programs' in_shardings reject differently-committed buffers.
            _, s_sh, c_sh = self._shardings
            tree = jax.device_put(tree, {"state": s_sh, "ctrl": c_sh})
        else:
            tree = jax.tree.map(jnp.asarray, tree)   # host -> device once
        self.state, self.ctrl = tree["state"], tree["ctrl"]
        for i, s in enumerate(manifest["extra"]["slots"]):
            if s is None:
                self.slot_req[i] = None
                self.slot_out[i] = []
                self.slot_remaining[i] = 0
            else:
                self.slot_req[i] = Request(
                    rid=s["rid"], prompt=np.asarray(s["prompt"], np.int32),
                    max_new_tokens=s["max_new_tokens"], eos_id=s["eos_id"])
                self.slot_out[i] = list(s["out"])
                self.slot_remaining[i] = s["remaining"]
        return manifest
