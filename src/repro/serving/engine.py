"""Batched serving engine: continuous batching over a fixed decode grid.

The engine owns one device-resident decode state of shape
``(max_batch, max_len)`` plus a device-resident per-slot control block
(last token, eos id, remaining budget, live flag, PRNG key) and runs three
jitted programs, all with **buffer donation** so XLA updates the KV /
recurrent state in place instead of allocating a copy per call:

  * ``prefill_into_slot`` — admission path. The prompt is split into its
    binary decomposition of power-of-two chunks (13 -> 8 + 4 + 1) and each
    chunk prefills into slot ``i`` via ``dynamic_update_slice`` under jit;
    chunk lengths are the only shape that varies, so a varied-length
    workload compiles at most ceil(log2(max_len)) prefill variants.
    Chunking (instead of right-padding to a bucket) keeps recurrent
    (RG-LRU / RWKV) and ring-buffer states exact: carry state threads
    across chunks and no pad token ever enters the recurrence.
  * ``decode_n`` — steady state. A ``jax.lax.scan`` runs up to
    ``drain_steps`` decode steps per dispatch when no admissions are
    pending; **sampling is fused into the jitted step** (one engine key
    split per step, then per slot), so only the (n, B) sampled tokens and
    done flags cross to host — never the (B, vocab) logits. Dead slots
    decode into a frozen trash position; the grid never reshapes.
  * ``admit_ctrl`` — writes a freshly-prefilled slot's control entries and
    samples its first token in-jit.

Continuous batching: when a sequence finishes (EOS or budget), its slot is
released and the next queued request prefills into it — the decode grid
keeps running; there is no global drain. While the queue is non-empty the
engine decodes one step at a time so a freed slot is refilled at the next
token boundary; once the queue drains it switches to multi-step dispatches.

Fault tolerance: ``snapshot``/``restore`` round-trip the device state +
control block through the checkpoint module and carry the per-slot host
bookkeeping AND the queued-but-unadmitted requests in the manifest, so a
preempted server resumes mid-generation with nothing resubmitted.

Self-healing (DESIGN.md §7): ``faults`` injects the NAND-SPIN device-fault
model — persistent write/stuck-at/retention faults corrupt the packed
planes at prepack, transient read disturb strikes inside the jitted decode
step (each step derives a disturb key from the engine key and activates
``repro.pim.faults.read_disturb_scope`` around the bit-serial matmuls).
``watchdog`` arms per-dispatch supervision: an in-memory shadow snapshot
before each dispatch, rollback + bounded-backoff retry (the training
stack's ``RestartPolicy``) on injected faults / device errors / non-finite
logits / blown deadlines, durable disk snapshots on a cadence, and — when
the failure budget is exhausted — graceful degradation to the float
fallback path so the bank keeps serving instead of crashing. Both default
to None, in which case every hot-loop program lowers to byte-identical HLO
(asserted in tests/test_faults.py).

PIM deployment: when ``cfg.pim`` is enabled the constructor prepacks every
projection weight into :class:`repro.core.packed.PackedWeight` — the
paper's program-subarrays-once step — so prefill/decode never re-calibrate,
re-quantize or re-pack a weight (DESIGN.md §3/§4).

Mesh-sharded serving (DESIGN.md §5): pass ``mesh`` (a ("data", "model")
mesh, e.g. ``repro.launch.mesh.make_serve_mesh``) and the engine maps the
paper's chip→bank→subarray hierarchy onto it — batch slots (chips) shard
on "data", every projection's output columns and the PackedWeight planes
(banks) on "model", and the bit-serial kernels tile subarrays into VMEM.
All three hot-loop programs compile with explicit in/out shardings equal to
the committed layouts, so under donation the steady-state decode loop never
inserts a resharding transfer — the only collectives are the tensor-parallel
partial-sum all-reduces and KB-scale scatter-index broadcasts (asserted on
compiled HLO in tests/test_serve_sharded.py).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import (
    decode_step, init_state, prefill_into_slot, prepack_params,
)
from repro.models.lm.config import ModelConfig

from .sampler import SamplerConfig, sample_per_slot


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                # -1: never
    deadline_ms: float | None = None   # end-to-end latency budget; enforced
                                       # by the gateway (queued AND
                                       # mid-generation), None = no deadline


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: list


def _pow2_chunks(n: int) -> list[int]:
    """Binary decomposition, largest first: 13 -> [8, 4, 1]."""
    out = []
    b = 1 << max(n.bit_length() - 1, 0)
    while n:
        if n >= b:
            out.append(b)
            n -= b
        b >>= 1
    return out


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_len: int = 512, sampler: SamplerConfig | None = None,
                 seed: int = 0, drain_steps: int = 8, mesh=None,
                 faults=None, watchdog=None, fault_injector=None,
                 keep_masters: bool = False, autotune: str = "off",
                 tuning_cache=None, pipeline_stages: int = 1,
                 pipeline_microbatches: int | None = None):
        if autotune not in ("off", "cost", "measure"):
            raise ValueError(
                f"autotune {autotune!r}: want 'off' | 'cost' | 'measure'")
        self.cfg = cfg
        self.mesh = mesh
        self.autotune = autotune
        self.pipeline_stages = max(1, int(pipeline_stages))
        self._pipe_mesh = None
        self._step_fn = decode_step
        if self.pipeline_stages > 1:
            # Pipeline-composed decode (DESIGN.md §11): the scanned unit
            # repetitions split over a dedicated 1-D ("stage",) mesh and
            # microbatches stream through GPipe-style. Mutually exclusive
            # with the ("data", "model") serving mesh — stage permutes and
            # GSPMD resharding do not compose in one program here.
            if mesh is not None:
                raise ValueError(
                    "pipeline_stages > 1 builds its own ('stage',) mesh; "
                    "pass mesh=None (data/model sharding and the pipeline "
                    "schedule are alternative decode compositions)")
            from repro.models.lm.model import layer_plan

            _, reps, _ = layer_plan(cfg)
            if reps % self.pipeline_stages:
                raise ValueError(
                    f"cannot pipeline: {reps} scanned repetition(s) do not "
                    f"factor into {self.pipeline_stages} equal stages")
            n_micro = pipeline_microbatches or self.pipeline_stages
            if max_batch % n_micro:
                raise ValueError(
                    f"cannot pipeline: max_batch {max_batch} does not split "
                    f"into {n_micro} equal microbatches")
            devs = jax.devices()
            if len(devs) < self.pipeline_stages:
                raise ValueError(
                    f"pipeline_stages={self.pipeline_stages} needs that many "
                    f"devices; have {len(devs)}")
            from jax.sharding import Mesh

            from repro.distributed.pipeline import pipeline_decode_step

            self._pipe_mesh = Mesh(
                np.asarray(devs[:self.pipeline_stages]), ("stage",))
            self._step_fn = partial(pipeline_decode_step,
                                    mesh=self._pipe_mesh,
                                    n_stages=self.pipeline_stages,
                                    n_microbatch=n_micro)
        # Routing telemetry (MoE only): per-step dropped-assignment fraction
        # ring buffers surfaced through :meth:`stats` for the gateway.
        self._moe_stats = bool(cfg.moe)
        if self._moe_stats:
            from .gateway import Ring

            self.rings = {"moe_drop_frac": Ring(512)}
        else:
            self.rings = {}
        self._tuning_cache_arg = tuning_cache
        self.tune_cache = None
        self.faults = faults
        self.watchdog = watchdog
        self.fault_injector = fault_injector   # test hook: raises per dispatch
        if mesh is not None and getattr(cfg.pim, "enabled", False) \
                and getattr(cfg.pim, "backend", "") == "pallas":
            # pallas_call has no GSPMD partitioning rule: under plain jit the
            # "model"-split planes would silently all-gather every step.
            # (kernels.bitserial_matmul_sharded is the shard_map primitive
            # for mesh-level pallas use; it is not wired into pim_linear yet.)
            raise ValueError(
                "mesh-sharded serving does not support pim backend 'pallas'; "
                "use 'popcount' or 'int-direct' (both partition under GSPMD)")
        # Deployment-time weight quantize+pack, exactly once (the paper
        # programs subarrays once): every prefill/decode after this reuses
        # the PackedWeight planes — no per-call re-calibration or re-pack.
        # With a mesh, the tree is committed to the serving layout here
        # (banks = "model"-axis column split; DESIGN.md §5). Persistent
        # device faults strike this programming pass (and, with
        # faults.checksum, repair from spares) before the tree ships.
        self.max_batch = max_batch
        self.params = prepack_params(params, cfg.pim, mesh=mesh,
                                     faults=faults)
        self._maybe_autotune()
        # The float masters survive under supervision (the degrade-to-float
        # fallback re-deploys from them) or on request (``keep_masters`` —
        # the gateway's precision-degradation tier calls :meth:`redeploy`).
        self._raw_params = params if (watchdog is not None
                                      or keep_masters) else None
        self.max_len = max_len
        self.sampler = sampler or SamplerConfig()
        self.drain_steps = max(1, drain_steps)
        self.state = init_state(cfg, max_batch, max_len)
        # Device-resident per-slot control block: consumed and produced by
        # the jitted decode under donation, so steady state moves no
        # control data between host and device.
        self.ctrl = {
            "last_tok": jnp.zeros((max_batch,), jnp.int32),
            "eos": jnp.full((max_batch,), -1, jnp.int32),
            "remaining": jnp.zeros((max_batch,), jnp.int32),
            "live": jnp.zeros((max_batch,), bool),
            "key": jax.random.PRNGKey(seed),
        }
        # Host bookkeeping mirrors (admission decisions + output assembly).
        self.slot_req: list = [None] * max_batch
        self.slot_out: list = [[] for _ in range(max_batch)]
        self.slot_remaining = np.zeros(max_batch, np.int32)
        self.queue: collections.deque = collections.deque()
        self.done: list = []
        self._cancelled: set = set()   # rids to release at the next boundary

        # Supervision state (inert unless watchdog/fault_injector set).
        from repro.training.fault_tolerance import (RestartPolicy,
                                                    StragglerDetector,
                                                    WatchdogConfig)

        wd = watchdog or WatchdogConfig()
        self._policy = RestartPolicy(wd.max_failures, wd.backoff_s)
        self._detector = StragglerDetector(wd.straggler_z)
        self._last_ok = True
        self.health = {"dispatches": 0, "rollbacks": 0, "stragglers": 0,
                       "snapshots": 0, "degraded": False}

        self._build_programs()

        # Lint-gate registration (repro.analysis; DESIGN.md §10): the
        # engine's jitted program families become lintable hot paths for
        # the CLI/CI gate. Weakly held — close() or GC unregisters.
        from repro import analysis as _analysis
        _analysis.register(self)

    def _maybe_autotune(self):
        """Attach per-weight TuneDecisions to the prepacked tree.

        Runs right after prepack (``__init__`` and every :meth:`redeploy`):
        the autotuner (repro.pim.autotune) picks backend + tiles per packed
        GEMM for this deployment's decode shape (m = max_batch) and records
        them in the tuning cache. Decisions are static pytree metadata —
        shardings, donation and checkpoint layouts are untouched; only
        which compiled program runs changes. The candidate set comes from
        ``autotune.default_backends(mesh)``, which already excludes pallas
        wherever the engine's own backend validation would (no GSPMD rule
        under a mesh, interpret-only off-TPU).
        """
        if self.autotune == "off" or not getattr(self.cfg.pim, "enabled",
                                                 False):
            return
        from repro.pim import autotune as _at

        if self.tune_cache is None:
            self.tune_cache = _at.as_cache(self._tuning_cache_arg)
        moe_kw = {}
        if self.cfg.moe:
            # Expert GEMMs batch every expert's capacity rows through one
            # vmapped dispatch — key their decisions on the (E*C, d, f)
            # batched shape, not the token batch (DESIGN.md §11).
            from repro.models.lm.moe import _capacity

            moe_kw["moe_m_hint"] = (self.cfg.moe.n_experts
                                    * _capacity(self.max_batch, self.cfg))
        self.params = _at.tune_tree(
            self.params, m_hint=self.max_batch,
            a_bits=self.cfg.pim.a_bits,
            backends=_at.default_backends(self.mesh),
            mode=self.autotune, cache=self.tune_cache, **moe_kw)

    def _build_programs(self):
        """(Re)compile the three hot-loop programs for the current cfg/params.

        Split out of ``__init__`` because the degrade-to-float fallback
        swaps ``cfg.pim``/``params`` and must rebuild against the new tree.

        With a mesh, every hot-loop program compiles with explicit in/out
        shardings equal to the committed layouts: the donated state/ctrl
        buffers then alias in place AND keep one stable layout across
        calls, so steady-state decode inserts no resharding transfer
        (asserted on HLO in tests/test_serve_sharded.py).
        """
        pf_kw, ad_kw, self._dec_kw = {}, {}, {}
        if self.mesh is not None:
            from repro.distributed import sharding as _sh

            mesh = self.mesh
            p_sh = _sh.serve_param_shardings(self.params, mesh)
            s_sh = _sh.serve_state_shardings(self.state, mesh)
            c_sh = _sh.serve_ctrl_shardings(self.ctrl, mesh)
            repl = _sh.replicated(mesh)
            self.state = jax.device_put(self.state, s_sh)
            self.ctrl = jax.device_put(self.ctrl, c_sh)
            self._shardings = (p_sh, s_sh, c_sh)
            stream = _sh.serve_stream_sharding(mesh, self.max_batch)
            pf_kw = dict(in_shardings=(p_sh, s_sh, repl, repl, repl),
                         out_shardings=(repl, s_sh))
            ad_kw = dict(in_shardings=(c_sh, repl, repl, repl, repl),
                         out_shardings=(c_sh, repl))
            dec_out = (s_sh, c_sh, stream, stream)
            if self._moe_stats:
                dec_out = dec_out + (repl,)        # (n,) drop-frac telemetry
            if self._transient:
                dec_out = dec_out + (repl,)        # the in-jit health flag
            self._dec_kw = dict(in_shardings=(p_sh, s_sh, c_sh),
                                out_shardings=dec_out)

        self._prefill = jax.jit(partial(self._prefill_impl, self.cfg),
                                donate_argnums=(1,), **pf_kw)
        self._admit_ctrl = jax.jit(partial(self._admit_impl, self.sampler),
                                   donate_argnums=(0,), **ad_kw)
        self._decode = {}   # scan length -> jitted decode_n program

    @property
    def _transient(self) -> bool:
        return self.faults is not None and self.faults.transient

    @contextlib.contextmanager
    def _activate(self):
        """Scope the engine's mesh to its own program calls.

        The sharding module's mesh is process-global (model code stays
        mesh-agnostic); tracing happens inside the jitted calls, so the
        mesh — and the serving KV layout flag consumed by
        ``constrain_kv_update`` — is activated around each call and
        restored after, instead of leaking into every later trace in the
        process (a mesh-free engine built afterwards must not inherit it).
        Mesh-free engines leave the global state alone entirely."""
        if self.mesh is None:
            yield
            return
        from repro.distributed import sharding as _sh

        prev_mesh, prev_serve = _sh.get_mesh(), _sh.get_serve_layout()
        _sh.set_mesh(self.mesh)
        _sh.set_serve_layout(True)
        try:
            yield
        finally:
            _sh.set_mesh(prev_mesh)
            _sh.set_serve_layout(prev_serve)

    # -- jitted bodies ------------------------------------------------------

    @staticmethod
    def _prefill_impl(cfg, params, state, tokens, slot, start):
        return prefill_into_slot(params, cfg, tokens, state, slot, start)

    @staticmethod
    def _admit_impl(sampler, ctrl, logits, slot, eos_id, n_new):
        """Sample the first token and write slot ``slot``'s control entries."""
        key, sub = jax.random.split(ctrl["key"])
        tok = sample_per_slot(logits[:, -1], sampler, sub[None])[0]
        eos_id = jnp.asarray(eos_id, jnp.int32)
        alive = (jnp.asarray(n_new, jnp.int32) > 1) & (tok != eos_id)

        def put(ref, val):
            return jax.lax.dynamic_update_slice(
                ref, jnp.asarray(val, ref.dtype)[None], (slot,))

        ctrl = dict(
            ctrl, key=key,
            last_tok=put(ctrl["last_tok"], tok),
            eos=put(ctrl["eos"], eos_id),
            remaining=put(ctrl["remaining"], jnp.asarray(n_new, jnp.int32) - 1),
            live=put(ctrl["live"], alive),
        )
        return ctrl, tok

    @staticmethod
    def _step_core(cfg, sampler, params, state, ctrl, faults=None,
                   step_fn=decode_step, want_stats=False):
        """One fused decode+sample step. Only (B,) tokens/flags leave jit.

        With transient faults, a disturb key splits off the engine key and
        the decode runs under ``read_disturb_scope`` — every bit-serial
        matmul senses a freshly disturbed view of its planes; an extra
        output reports in-jit logit health (the NaN watchdog probe). With
        ``faults=None`` the traced program is byte-identical to before.

        ``step_fn`` is the decode-step implementation — the sequential
        ``decode_step`` or the pipeline-composed
        ``distributed.pipeline.pipeline_decode_step`` partial.
        ``want_stats`` (MoE engines) appends the per-step routing
        drop-fraction scalar to the outputs. Extra-output order is fixed:
        (state, ctrl, tok, done[, drop][, ok]).
        """
        def run(st):
            return step_fn(params, cfg, ctrl["last_tok"][:, None], st,
                           return_stats=want_stats)

        if faults is not None and faults.transient:
            from repro.pim.faults import read_disturb_scope

            key0, dkey = jax.random.split(ctrl["key"])
            ctrl = dict(ctrl, key=key0)
            with read_disturb_scope(faults, dkey):
                out = run(state)
        else:
            out = run(state)
        if want_stats:
            logits, new_state, st_stats = out
        else:
            logits, new_state = out
        key, sub = jax.random.split(ctrl["key"])
        keys = jax.random.split(sub, ctrl["last_tok"].shape[0])
        nxt = sample_per_slot(logits[:, 0], sampler, keys)
        nxt = jnp.where(ctrl["live"], nxt, ctrl["last_tok"])
        remaining = ctrl["remaining"] - ctrl["live"].astype(jnp.int32)
        done = ctrl["live"] & ((nxt == ctrl["eos"]) | (remaining <= 0))
        # Dead slots do not advance: their trash KV writes land on one row,
        # which the next occupant overwrites before it becomes attendable.
        new_state["length"] = jnp.where(ctrl["live"], new_state["length"],
                                        state["length"])
        ctrl = dict(ctrl, key=key, last_tok=nxt, remaining=remaining,
                    live=ctrl["live"] & ~done)
        extra = ()
        if want_stats:
            extra = extra + (st_stats["moe_drop_frac"],)
        if faults is not None and faults.transient:
            extra = extra + (jnp.isfinite(logits).all(),)
        return (new_state, ctrl, nxt, done) + extra

    @staticmethod
    def _decode_impl(cfg, sampler, faults, step_fn, want_stats, n,
                     params, state, ctrl):
        """``n`` fused decode steps per dispatch; emits (n, B) tokens/flags
        (+ the (n,) per-step drop fractions on MoE engines, + one
        dispatch-level health flag when transient faults are on)."""
        transient = faults is not None and faults.transient

        def body(carry, _):
            st, ct = carry
            out = ServeEngine._step_core(cfg, sampler, params, st, ct,
                                         faults, step_fn, want_stats)
            return (out[0], out[1]), out[2:]

        (state, ctrl), ys = jax.lax.scan(body, (state, ctrl), None, length=n)
        ys = list(ys)
        out = [state, ctrl, ys.pop(0), ys.pop(0)]
        if want_stats:
            out.append(ys.pop(0))           # (n,) per-step drop fractions
        if transient:
            out.append(ys.pop(0).all())
        return tuple(out)

    def _decode_fn(self, n: int):
        fn = self._decode.get(n)
        if fn is None:
            fn = jax.jit(partial(self._decode_impl, self.cfg, self.sampler,
                                 self.faults, self._step_fn,
                                 self._moe_stats, n),
                         donate_argnums=(1, 2), **self._dec_kw)
            self._decode[n] = fn
        return fn

    def hot_paths(self):
        """Declare the three hot-loop program families for the lint gate.

        Budgets encode the serving performance story (DESIGN.md §5/§10):
        decode must stay free of all-to-all and weight/KV-sized gathers
        with collective counts flat in the drain length, every donated
        state/ctrl buffer must actually alias, and no host sync, f64 or
        illegal autotune tile may appear in any hot program. Programs
        lower under :meth:`_activate`, exactly like the real dispatch."""
        from repro import analysis as _an

        # The all-to-all budget is 0 — decode must not reshard — except on
        # the packed expert-parallel MoE layout (mesh "model" axis divides
        # E, weights prepacked): there the dispatch/combine all-to-all is
        # the *designed* collective (DESIGN.md §11), budgeted per FFN site
        # (dispatch + combine + the small occupancy mask per MoE layer).
        a2a_cap = 0
        if self.cfg.moe and self.mesh is not None \
                and getattr(self.cfg.pim, "enabled", False):
            from repro.distributed import sharding as _sh
            from repro.models.lm.model import layer_plan

            ms = _sh.axis_size(self.mesh, "model")
            if ms > 1 and self.cfg.moe.n_experts % ms == 0:
                unit, _, rest = layer_plan(self.cfg)
                sites = sum(k != "rwkv" for k in unit + rest)
                a2a_cap = 4 * max(sites, 1)
        base = dict(
            collectives=(("all-to-all", a2a_cap),),
            compute_dtype="bf16" if str(self.cfg.dtype) == "bfloat16"
            else None,
            m_hint=self.max_batch,
            pallas_ok=self.mesh is None,
        )
        # Pipelined decode adds exactly one collective class of its own:
        # the inter-stage permute (plus the drain psum all-reduces, which
        # the byte bound and scan-flatness already police). Cap it so a
        # permute can never creep inside the per-rep layer scan.
        dec_coll = base["collectives"]
        if self.pipeline_stages > 1:
            dec_coll = dec_coll + (("collective-permute", 4),)
        tokens = jnp.zeros((1, 1), jnp.int32)
        logits = jnp.zeros((1, 1, self.cfg.vocab),
                           jnp.dtype(self.cfg.dtype))
        dec_name = ("lm.decode.pipelined" if self.pipeline_stages > 1
                    else "lm.decode")
        return [
            _an.HotPath(
                "lm.prefill", "lm",
                _an.Budget(donate=(1,), max_gather_bytes=None, **base),
                [_an.Program("chunk=1", self._prefill,
                             (self.params, self.state, tokens, 0, 0))],
                context=self._activate),
            _an.HotPath(
                "lm.admit", "lm",
                _an.Budget(donate=(0,), max_gather_bytes=None, **base),
                [_an.Program("slot", self._admit_ctrl,
                             (self.ctrl, logits, 0, -1, 4))],
                context=self._activate),
            _an.HotPath(
                dec_name, "lm",
                _an.Budget(donate=(1, 2), max_gather_bytes=16384,
                           scan_flat=True,
                           **dict(base, collectives=dec_coll)),
                [_an.Program(f"n={n}", self._decode_fn(n),
                             (self.params, self.state, self.ctrl))
                 for n in sorted({1, self.drain_steps})],
                context=self._activate),
        ]

    def close(self):
        """Engine teardown: deregister from the lint gate and reset the
        tuning cache so a later deploy sharing the cache object re-reads
        its (possibly repaired) backing file instead of serving this
        deployment's stale fallback memo."""
        from repro import analysis as _analysis
        _analysis.unregister(self)
        if self.tune_cache is not None:
            self.tune_cache.reset()

    # -- public API ---------------------------------------------------------

    def validate(self, prompt, max_new_tokens: int):
        """Admission-time request validation. ``_admit`` writes the prompt
        into the (max_batch, max_len) decode grid at positions 0..L-1 and
        each generated token's KV at the running length, so a request with
        ``L + max_new_tokens > max_len`` would silently write past the grid
        (``dynamic_update_slice`` clamps — the tail tokens corrupt the last
        row instead of raising). Reject it here, with the empty prompt (no
        logits to sample the first token from) and a non-positive budget."""
        n = len(prompt)
        if n == 0:
            raise ValueError("empty prompt: nothing to prefill, no final "
                             "logits to sample the first token from")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens={max_new_tokens} must be >= 1")
        if n + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({n} tokens) + max_new_tokens ({max_new_tokens}) "
                f"exceeds the decode grid (max_len={self.max_len}); the "
                "overflow would clamp into the grid's last row")

    def submit(self, req: Request):
        self.validate(req.prompt, req.max_new_tokens)
        self.queue.append(req)

    def cancel(self, rid: int) -> str | None:
        """Cancel a request. Queued: removed immediately. Mid-generation:
        its slot is released at the next token boundary through the same
        slot-free path a natural completion takes — the dead slot decodes
        into its frozen trash position until then, and the next occupant's
        prefill zeroes the recurrent carries (the PR 3 slot-reuse guard).
        Returns "queued" / "active" for what was cancelled, None if the rid
        is unknown (already completed or never submitted)."""
        for i, r in enumerate(self.queue):
            if r.rid == rid:
                del self.queue[i]
                return "queued"
        for r in self.slot_req:
            if r is not None and r.rid == rid:
                self._cancelled.add(rid)
                return "active"
        return None

    @property
    def n_free_slots(self) -> int:
        """Slots an admission could land in right now: free grid slots not
        already spoken for by queued requests. The gateway uses this to
        admit exactly what the grid can take (its own queues stay the only
        place requests wait, so shedding decisions are centralized)."""
        free = sum(r is None for r in self.slot_req)
        return max(0, free - len(self.queue))

    def _release_cancelled(self):
        """Free cancelled slots at a token boundary: clear the host slot
        (continuous batching refills it on the next ``_admit``) and kill the
        slot's device liveness so the grid decodes it into the trash row."""
        hit = [i for i, r in enumerate(self.slot_req)
               if r is not None and r.rid in self._cancelled]
        self._cancelled.clear()
        if not hit:
            return
        mask = np.zeros(self.max_batch, bool)
        mask[hit] = True
        mask = jnp.asarray(mask)
        ctrl = dict(self.ctrl,
                    live=self.ctrl["live"] & ~mask,
                    remaining=jnp.where(mask, 0, self.ctrl["remaining"]))
        if self.mesh is not None:
            # Keep the control block committed to the canonical layout —
            # the hot-loop programs' in_shardings reject drifted buffers.
            _, _, c_sh = self._shardings
            ctrl = jax.device_put(ctrl, c_sh)
        self.ctrl = ctrl
        for i in hit:
            self.slot_req[i] = None
            self.slot_out[i] = []
            self.slot_remaining[i] = 0

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        """Prefill queued requests into free slots, chunked power-of-two."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            prompt = np.asarray(req.prompt, np.int32)
            pos, logits = 0, None
            with self._activate():
                for c in _pow2_chunks(len(prompt)):
                    tokens = jnp.asarray(prompt[pos:pos + c], jnp.int32)[None]
                    logits, self.state = self._prefill(
                        self.params, self.state, tokens, slot, pos)
                    pos += c
                self.ctrl, tok = self._admit_ctrl(
                    self.ctrl, logits, slot, req.eos_id, req.max_new_tokens)
            first = int(tok)
            self.slot_out[slot] = [first]
            if req.max_new_tokens <= 1 or first == req.eos_id:
                self.done.append(Completion(req.rid, self.slot_out[slot]))
                continue
            self.slot_req[slot] = req
            self.slot_remaining[slot] = req.max_new_tokens - 1

    def step(self) -> list:
        """Admit + decode (one step, or a drain of up to ``drain_steps``
        fused steps when no admissions are pending); returns completions.

        With a watchdog (or fault injector) armed, the dispatch runs
        supervised: shadow snapshot -> dispatch -> health checks, with
        rollback + backoff retry on failure and degradation to the float
        path once the failure budget is spent (see :meth:`_step_supervised`).
        """
        if self._cancelled:
            # Before the supervised shadow: a rollback must not resurrect a
            # cancelled request (the shadow then captures post-cancel state).
            self._release_cancelled()
        if self.watchdog is None and self.fault_injector is None:
            return self._step_once()
        return self._step_supervised()

    def _step_once(self) -> list:
        """One unsupervised dispatch (the pre-watchdog ``step()`` body)."""
        self._admit()
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return self._drain_done()
        if self.queue:
            n = 1   # keep admissions responsive: a slot may free next token
        else:
            cap = max(1, min(self.drain_steps,
                             int(max(self.slot_remaining[i] for i in live))))
            n = 1 << (cap.bit_length() - 1)   # pow2 -> bounded compile count
        with self._activate():
            res = self._decode_fn(n)(self.params, self.state, self.ctrl)
        res = list(res)
        self.state, self.ctrl, toks, dones = res[:4]
        res = res[4:]
        if self._moe_stats:
            for v in np.asarray(res.pop(0)):
                self.rings["moe_drop_frac"].push(float(v))
        if self._transient:
            self._last_ok = bool(res.pop(0))
        toks = np.asarray(toks)
        dones = np.asarray(dones)
        for k in range(n):
            for i in list(live):
                req = self.slot_req[i]
                self.slot_out[i].append(int(toks[k, i]))
                self.slot_remaining[i] -= 1
                if dones[k, i]:
                    self.done.append(Completion(req.rid, self.slot_out[i]))
                    self.slot_req[i] = None
                    live.remove(i)
        return self._drain_done()

    def _drain_done(self):
        out, self.done = self.done, []
        return out

    def stats(self) -> dict:
        """Live telemetry snapshot: supervision health plus the ring-buffer
        channels (MoE engines: ``moe_drop_frac`` — per-decode-step fraction
        of top-k routing assignments dropped at expert capacity). The
        gateway merges this into its own :meth:`Gateway.stats` payload so
        operators see routing overflow next to goodput/shed counts."""
        out = {"health": dict(self.health)}
        for name, ring in self.rings.items():
            v = ring.values()
            out[name] = dict(ring.percentiles(),
                             n=len(ring),
                             mean=float(v.mean()) if len(ring) else None)
        return out

    # -- watchdog supervision (DESIGN.md §7) --------------------------------

    def _shadow(self):
        """In-memory rollback point: device buffers copied (the dispatch
        consumes the originals under donation) + host bookkeeping."""
        dev = jax.tree.map(jnp.copy, {"state": self.state, "ctrl": self.ctrl})
        return (dev, list(self.slot_req), [list(o) for o in self.slot_out],
                self.slot_remaining.copy(), collections.deque(self.queue),
                list(self.done))

    def _restore_shadow(self, shadow):
        dev, reqs, outs, rem, queue, done = shadow
        self.state, self.ctrl = dev["state"], dev["ctrl"]
        self.slot_req, self.slot_out = reqs, outs
        self.slot_remaining, self.queue, self.done = rem, queue, done

    def _step_supervised(self) -> list:
        """Shadow -> dispatch -> health checks, rollback + retry on failure.

        Failure channels: the ``fault_injector`` test hook raising, a device
        runtime error, the in-jit non-finite-logit flag (transient faults),
        and a dispatch exceeding ``deadline_s``. Each failure restores the
        shadow (no token is double-emitted: completions drained by the
        failed dispatch are part of the shadow) and retries after
        ``RestartPolicy`` backoff; a spent budget degrades to the float
        path (``degrade=True``) or re-raises.
        """
        wd = self.watchdog
        while True:
            shadow = self._shadow()
            # Monotonic: an NTP step of the wall clock must not blow the
            # dispatch deadline and burn the failure budget spuriously.
            t0 = time.monotonic()
            try:
                if self.fault_injector is not None:
                    self.fault_injector(self.health["dispatches"])
                out = self._step_once()
                dt = time.monotonic() - t0
                if self._detector.observe(dt):
                    self.health["stragglers"] += 1
                if wd is not None and wd.deadline_s is not None \
                        and dt > wd.deadline_s:
                    raise RuntimeError(
                        f"watchdog: dispatch took {dt:.3f}s "
                        f"> deadline {wd.deadline_s}s")
                if not self._last_ok:
                    raise RuntimeError(
                        "watchdog: non-finite logits in dispatch")
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                self._restore_shadow(shadow)
                self._last_ok = True
                self.health["rollbacks"] += 1
                try:
                    wait = self._policy.on_failure()
                except RuntimeError:
                    if wd is not None and wd.degrade \
                            and self._raw_params is not None \
                            and getattr(self.cfg.pim, "enabled", False):
                        print(f"[serve-watchdog] budget spent ({e!r}); "
                              "degrading to float path", flush=True)
                        self._degrade_to_float()
                        continue
                    raise
                print(f"[serve-watchdog] dispatch failed: {e!r}; "
                      f"rollback + retry in {wait:.2f}s", flush=True)
                time.sleep(min(wait, 0.05))  # bounded for tests; real: full
                continue
            self.health["dispatches"] += 1
            self._policy.record_progress(self.health["dispatches"])
            if wd is not None and wd.snap_every and wd.ckpt_dir \
                    and self.health["dispatches"] % wd.snap_every == 0:
                self.snapshot(wd.ckpt_dir, step=self.health["dispatches"])
                self.health["snapshots"] += 1
            return out

    def redeploy(self, pim_cfg):
        """Re-prepack from the float masters under a new PIM config and
        rebuild the hot-loop programs — the PR 5 degrade machinery,
        parameterized so the gateway's degradation ladder can move a serving
        cohort to a cheaper precision (or back) under sustained overload.
        Decode state/ctrl carry over — the KV grid is representation-
        independent — so in-flight generations continue on the new path.
        Requires the float masters (``keep_masters=True`` or a watchdog)."""
        if self._raw_params is None:
            raise RuntimeError(
                "redeploy needs the float masters; construct the engine "
                "with keep_masters=True (or a watchdog)")
        self.cfg = dataclasses.replace(self.cfg, pim=pim_cfg)
        self.params = prepack_params(self._raw_params, pim_cfg,
                                     mesh=self.mesh, faults=self.faults)
        self._maybe_autotune()   # new precision -> fresh (cached) decisions
        self._build_programs()

    def _degrade_to_float(self):
        """Sustained fault pressure: re-deploy this bank on the float
        fallback from the golden masters and keep serving (graceful
        degradation instead of a crash)."""
        from repro.training.fault_tolerance import RestartPolicy

        self.faults = None
        self._last_ok = True
        self.redeploy(dataclasses.replace(self.cfg.pim, enabled=False))
        wd = self.watchdog
        self._policy = RestartPolicy(wd.max_failures, wd.backoff_s)
        self.health["degraded"] = True

    def run(self, max_steps: int = 10_000, strict: bool = False) -> list:
        """Drive until queue + slots drain; returns all completions.

        Exhausting ``max_steps`` with work still in flight emits a
        ``RuntimeWarning`` naming the stranded requests — or raises when
        ``strict=True`` — instead of returning silently as if drained.
        """
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.queue and all(r is None for r in self.slot_req):
                return out
        live = [r.rid for r in self.slot_req if r is not None]
        queued = [r.rid for r in self.queue]
        if live or queued:
            msg = (f"run(max_steps={max_steps}) exited with "
                   f"{len(live) + len(queued)} stranded request(s): "
                   f"rids {live} mid-generation, rids {queued} queued")
            if strict:
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return out

    # -- fault tolerance ----------------------------------------------------

    @staticmethod
    def _req_dict(r: Request) -> dict:
        return {"rid": r.rid, "prompt": np.asarray(r.prompt).tolist(),
                "max_new_tokens": r.max_new_tokens, "eos_id": r.eos_id,
                "deadline_ms": r.deadline_ms}

    @staticmethod
    def _req_from(s: dict) -> Request:
        return Request(rid=s["rid"], prompt=np.asarray(s["prompt"], np.int32),
                       max_new_tokens=s["max_new_tokens"], eos_id=s["eos_id"],
                       deadline_ms=s.get("deadline_ms"))

    def snapshot(self, ckpt_dir: str, step: int = 0):
        """Checkpoint device state + control block + slot bookkeeping +
        the queued-but-unadmitted requests (re-enqueued by ``restore``, so
        nothing needs resubmitting). Safe mid-generation: saving copies to
        host, it does not consume the donated device buffers."""
        from repro.training import checkpoint as ckpt

        slots = []
        for i, r in enumerate(self.slot_req):
            slots.append(None if r is None else dict(
                self._req_dict(r),
                out=list(self.slot_out[i]),
                remaining=self.slot_remaining[i],
            ))
        extra = {"slots": slots,
                 "queue": [self._req_dict(r) for r in self.queue],
                 "max_batch": self.max_batch,
                 "max_len": self.max_len}
        if self.tune_cache is not None:
            # Tuning decisions ride the manifest so a restored engine skips
            # re-ranking (and re-measuring) every deployment GEMM.
            extra["tuning"] = self.tune_cache.to_extra()
        ckpt.save(ckpt_dir, step, {"state": self.state, "ctrl": self.ctrl},
                  extra=extra)

    def restore(self, ckpt_dir: str, step: int | None = None):
        """Resume mid-generation from :meth:`snapshot` (same cfg/geometry)."""
        from repro.training import checkpoint as ckpt

        like = {"state": self.state, "ctrl": self.ctrl}
        tree, manifest = ckpt.restore(ckpt_dir, like, step=step)
        if self.mesh is not None:
            # Commit straight to the canonical serving layout — the hot-loop
            # programs' in_shardings reject differently-committed buffers.
            _, s_sh, c_sh = self._shardings
            tree = jax.device_put(tree, {"state": s_sh, "ctrl": c_sh})
        else:
            tree = jax.tree.map(jnp.asarray, tree)   # host -> device once
        self.state, self.ctrl = tree["state"], tree["ctrl"]
        for i, s in enumerate(manifest["extra"]["slots"]):
            if s is None:
                self.slot_req[i] = None
                self.slot_out[i] = []
                self.slot_remaining[i] = 0
            else:
                self.slot_req[i] = self._req_from(s)
                self.slot_out[i] = list(s["out"])
                self.slot_remaining[i] = s["remaining"]
        # Re-enqueue requests that were queued but unadmitted at snapshot
        # time (absent in pre-queue-persistence checkpoints).
        self.queue = collections.deque(
            self._req_from(s) for s in manifest["extra"].get("queue", []))
        if self.tune_cache is not None:
            self.tune_cache.merge_extra(manifest["extra"].get("tuning"))
        return manifest
