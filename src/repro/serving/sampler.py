"""Token samplers: greedy / temperature / top-k, batched and jit-safe."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0     # 0 -> greedy
    top_k: int = 0               # 0 -> no truncation


def _prep_logits(logits: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """Shared temperature scaling + top-k truncation (both samplers)."""
    l = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k:
        kth = jax.lax.top_k(l, cfg.top_k)[0][..., -1:]
        l = jnp.where(l < kth, -jnp.inf, l)
    return l


def sample(logits: jax.Array, cfg: SamplerConfig, key) -> jax.Array:
    """logits (B, V) -> token ids (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = _prep_logits(logits, cfg)
    return jax.random.categorical(key, l, axis=-1).astype(jnp.int32)


def sample_per_slot(logits: jax.Array, cfg: SamplerConfig, keys) -> jax.Array:
    """logits (B, V), keys (B, 2) -> token ids (B,); row i uses keys[i].

    The serving decode loop threads one engine key per step and splits it
    per slot, so a slot's sample stream is independent of the batch
    composition around it and a key is never reused across steps (unlike
    deriving a key from summed slot positions, which collides whenever two
    steps share the same sum)."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = _prep_logits(logits, cfg)
    return jax.vmap(
        lambda row, k: jax.random.categorical(k, row))(l, keys).astype(jnp.int32)
