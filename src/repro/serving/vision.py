"""Batched CNN serving engine: micro-batched vision inference on the fused
conv path (DESIGN.md §6).

The LM :class:`~repro.serving.engine.ServeEngine` gives the paper's LM
deployment its production properties — prepack-once weights, donated jitted
hot programs, the ("data", "model") serving mesh. The paper itself is a
*CNN* accelerator, and this engine gives the conv stack the same treatment:

  * **Queue + power-of-two micro-batching.** Requests carry (image, model,
    precision ``<W:I>``). The engine groups the queue head's (model,
    precision, image-shape) cohort and dispatches the largest power-of-two
    bucket that fits (5 queued -> 4 + 1), so a varied load compiles at most
    ``log2(max_batch) + 1`` forward variants per (model, cfg) — the same
    bounded-compile-count argument as the LM engine's pow2 prompt chunks.
  * **Prepack exactly once per (model, cfg).** The first request of a
    (model, precision) pair quantizes + packs every conv/fc weight into
    :class:`PackedConvWeight`/:class:`PackedWeight` (the paper's
    program-subarrays-once step) and caches the tree; every later bucket of
    that pair reuses it — no per-call weight calibration, quantization or
    bit-plane packing. Conv layers then run the prepacked fast path:
    materialized im2col for 1x1/small maps, the fused implicit-im2col
    Pallas kernel where :func:`repro.core.fuse_conv_heuristic` fires
    (``backend="pallas"``).
  * **Donated jitted forward.** Each bucket's forward is one jitted program
    with the image batch donated, so XLA reuses the input buffer for
    activations instead of holding both alive.
  * **Mesh-sharded serving.** With a ("data", "model") mesh
    (``repro.launch.mesh.make_serve_mesh``) the paper's chip→bank mapping
    applies to vision exactly as to LM decode: the micro-batch (chips)
    shards on "data", and every conv's output channels O / every FC's
    output columns (banks) on "model" — including both packed
    representations (``PackedConvWeight.mat`` planes/codes/col_sums on
    their N dim and the ``fused_planes`` on O; see
    ``distributed/sharding.py::serve_cnn_param_shardings`` and
    ``core/packed.py::shard_packed``). Forwards compile with explicit
    in/out shardings, and the no-large-all-gather HLO invariant is asserted
    in tests/test_vision_engine.py, mirroring tests/test_serve_sharded.py.
    ``backend="pallas"`` is rejected with a mesh for the same reason as the
    LM engine: ``pallas_call`` has no GSPMD rule.

Numerics: a bucket's logits are bit-identical to jitted ``model.apply`` on
the same stacked batch with the same ``PIMQuantConfig`` under the same
device topology — prepacking produces the exact codes per-call
quantization would, activation calibration is per-batch in both cases, and
the serving machinery (bucketing, caching, donation) adds zero numerics.
Across topologies the quantized integer core is partition-exact and the
float path replicates (bitwise); only the quantized paths' float
dequantization epilogue picks up ULP-level topology-dependent FMA
differences (DESIGN.md §6). Asserted in tests/test_vision_engine.py for
the float, int-direct and popcount paths, single-device and on a forced
8-device mesh.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import re
import time
import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PIMQuantConfig
from repro.models.cnn import alexnet, resnet, vgg
from repro.models.cnn.layers import prepack_params as _prepack_cnn

# The paper CNN zoo, keyed by serving name — the single registry the
# engine, launcher (--cnn-model) and cnn benchmark all resolve against.
MODEL_ZOO = {"alexnet": alexnet, "resnet50": resnet, "vgg19": vgg}

_PRECISION = re.compile(r"^<(\d+):(\d+)>$")


def parse_precision(precision: str | None) -> tuple[int, int] | None:
    """``"<W:I>"`` -> (w_bits, a_bits); None/"float" -> None (fp path)."""
    if precision is None or precision in ("float", "fp32"):
        return None
    m = _PRECISION.match(precision)
    if not m:
        raise ValueError(
            f"precision {precision!r}: want '<W:I>' (e.g. '<8:8>') or None")
    return int(m.group(1)), int(m.group(2))


@dataclasses.dataclass(eq=False)   # identity equality: ndarray fields make
class VisionRequest:               # field-wise __eq__ ambiguous, and the
    rid: int                       # queue removes by identity anyway
    image: np.ndarray               # (H, W, C) float
    model: str = "resnet50"
    precision: str | None = "<8:8>"  # "<W:I>" | None (float forward)
    deadline_ms: float | None = None  # latency budget; gateway-enforced


@dataclasses.dataclass
class VisionCompletion:
    rid: int
    logits: np.ndarray              # (num_classes,)
    top1: int
    batch: int                      # bucket size this request rode in


class VisionEngine:
    """Continuous micro-batched CNN inference over a model registry.

    ``models`` maps a model name to its float param tree (names resolve
    against the paper zoo: alexnet / resnet50 / vgg19) or to an explicit
    ``(module, params)`` pair for custom CNNs — any module exposing
    ``apply(params, x, cfg=...)`` over ``repro.core.pim_conv2d`` works.

    ``backend`` picks the Eq. 1 execution strategy for every quantized
    request ("int-direct" | "popcount" | "mxu-plane" | "pallas"); requests
    pick their own precision. ``max_batch`` is the largest micro-batch
    bucket (rounded down to a power of two).
    """

    def __init__(self, models: dict, backend: str = "int-direct",
                 max_batch: int = 8, mesh=None, faults=None, watchdog=None,
                 fault_injector=None, seed: int = 0, autotune: str = "off",
                 tuning_cache=None):
        if autotune not in ("off", "cost", "measure"):
            raise ValueError(
                f"autotune {autotune!r}: want 'off' | 'cost' | 'measure'")
        if mesh is not None and backend == "pallas":
            # Same rule as ServeEngine: pallas_call has no GSPMD partitioning
            # rule, so the "model"-split planes would silently all-gather on
            # every bucket. Use "popcount" or "int-direct" on a mesh.
            raise ValueError(
                "mesh-sharded vision serving does not support backend "
                "'pallas'; use 'popcount' or 'int-direct'")
        self._models = {}
        for name, entry in models.items():
            if isinstance(entry, tuple):
                module, params = entry
            else:
                if name not in MODEL_ZOO:
                    raise ValueError(
                        f"unknown model {name!r} (zoo: {sorted(MODEL_ZOO)}); "
                        "pass (module, params) for custom CNNs")
                module, params = MODEL_ZOO[name], entry
            self._models[name] = (module, params)
        self.backend = backend
        self.max_batch = 1 << (max(1, max_batch).bit_length() - 1)
        self.mesh = mesh
        # Autotune (repro.pim.autotune): conv GEMM shapes depend on the
        # image size, known only at dispatch, so tuned trees are derived
        # lazily per (model, precision, image-hw, bucket) — cheap static-
        # metadata wrappers over the packed tree, cached in ``_tuned``.
        # Vision always ranks by cost model (measurement is a GEMM-level
        # facility; the "measure" knob still upgrades the FC decisions).
        self.autotune = autotune
        self._tuning_cache_arg = tuning_cache
        self.tune_cache = None
        self._tuned: dict = {}      # (model, precision, h, w, bucket) -> tree
        self.queue: collections.deque = collections.deque()
        self._packed: dict = {}     # (model, precision) -> param tree
        self._golden: dict = {}     # (model, precision) -> fault-free tree
        self._param_sh: dict = {}   # (model, precision) -> sharding tree
        self._fwd: dict = {}        # (model, precision, bucket) -> jitted fn
        # Self-healing (DESIGN.md §7): persistent faults strike each
        # (model, precision) programming pass; transient read disturb
        # strikes every quantized dispatch via a per-dispatch key. The
        # watchdog retries failed buckets (repairing flagged columns from
        # the golden tree when the checksum is armed) and degrades a cohort
        # to the float path once its failure budget is spent.
        from repro.training.fault_tolerance import (RestartPolicy,
                                                    WatchdogConfig)

        self.faults = faults
        self.watchdog = watchdog
        self.fault_injector = fault_injector   # test hook: raises per dispatch
        self._wd = wd = watchdog or WatchdogConfig()
        self._policy = RestartPolicy(wd.max_failures, wd.backoff_s)
        self._degraded: set = set()            # (model, precision) cohorts
        self._fault_key = jax.random.PRNGKey(seed)
        self.health = {"dispatches": 0, "rollbacks": 0, "repairs": 0,
                       "repaired_cols": 0, "degraded": []}
        # Lint-gate registration (repro.analysis; DESIGN.md §10). Image
        # shapes are only known at dispatch, so _dispatch records each
        # (model, precision, bucket) -> image shape for hot_paths().
        self._hot_shapes: dict = {}
        from repro import analysis as _analysis
        _analysis.register(self)

    # -- mesh scoping (same contract as ServeEngine._activate) --------------

    @contextlib.contextmanager
    def _activate(self, quantized: bool = True):
        """Scope the mesh (and the CNN serving layout flag consumed by
        ``constrain_cnn_conv_input``/``_output``) to the engine's own
        program calls, like ``ServeEngine._activate``.

        Float buckets never activate it: their jit is fully replicated, and
        tracing them under the global mesh would let ``_constrain_weight``
        split the FC contractions — a float partial-sum reorder that breaks
        the bit-identity contract."""
        if self.mesh is None or not quantized:
            yield
            return
        from repro.distributed import sharding as _sh

        prev_mesh, prev_cnn = _sh.get_mesh(), _sh.get_cnn_serve_layout()
        _sh.set_mesh(self.mesh)
        _sh.set_cnn_serve_layout(True)
        try:
            yield
        finally:
            _sh.set_mesh(prev_mesh)
            _sh.set_cnn_serve_layout(prev_cnn)

    # -- caches --------------------------------------------------------------

    def _cfg(self, precision: str | None) -> PIMQuantConfig | None:
        bits = parse_precision(precision)
        if bits is None:
            return None
        return PIMQuantConfig(w_bits=bits[0], a_bits=bits[1],
                              backend=self.backend)

    def _packed_params(self, model: str, precision: str | None):
        """Quantize+pack (and mesh-commit) exactly once per (model, cfg).

        With a fault model, the freshly programmed quantized tree is
        corrupted by the persistent fault mechanisms (each (model,
        precision) pair gets its own key fold); the fault-free tree is kept
        as the golden master the checksum-repair path re-programs from.
        """
        mkey = (model, precision)
        tree = self._packed.get(mkey)
        if tree is None:
            module, params = self._models[model]
            cfg = self._cfg(precision)
            tree = _prepack_cnn(params, cfg) if cfg is not None else params
            if cfg is not None and self.faults is not None \
                    and self.faults.persistent:
                from repro.pim.faults import inject_tree

                self._golden[mkey] = tree
                key = jax.random.fold_in(self.faults.key(),
                                         len(self._golden))
                tree, _ = inject_tree(tree, self.faults, key)
            if self.mesh is not None:
                from repro.distributed import sharding as _sh

                p_sh = _sh.serve_cnn_param_shardings(
                    tree, self.mesh, quantized=cfg is not None)
                tree = jax.device_put(tree, p_sh)
                self._param_sh[mkey] = p_sh
            self._packed[mkey] = tree
        return tree

    def _repair(self, model: str, precision: str | None) -> int:
        """Checksum-scan the cohort's packed tree and re-program flagged
        columns from the golden master (bounded by the spare budget).
        Returns the number of repaired columns."""
        mkey = (model, precision)
        golden = self._golden.get(mkey)
        if golden is None or self.faults is None or not self.faults.checksum:
            return 0
        from repro.pim.faults import repair_tree

        tree, report = repair_tree(self._packed[mkey], golden,
                                   self.faults.spare_cols,
                                   self.faults.subarray_cols)
        if self.mesh is not None:
            tree = jax.device_put(tree, self._param_sh[mkey])
        self._packed[mkey] = tree
        # Tuned wrappers hold references to the pre-repair arrays; drop
        # them so the next dispatch re-derives from the repaired tree (the
        # decisions themselves come back instantly from the tuning cache).
        self._tuned = {k: v for k, v in self._tuned.items()
                       if k[:2] != mkey}
        return report["repaired_cols"]

    def _tuned_params(self, model: str, precision: str | None, shape):
        """Tuned view of the packed tree for one (cohort, image, bucket).

        Decisions are per-GEMM: FC weights tune on the bucket's row count,
        conv weights on the im2col row bound ``batch * H * W`` (the
        stride-1 upper bound — the backend crossover is driven by the
        plane-pair count, which the bound preserves). Attaching decisions
        is ``dataclasses.replace`` on static metadata, so the committed
        (possibly mesh-sharded) buffers are reused as-is.
        """
        n, h, w, _ = shape
        tkey = (model, precision, h, w, n)
        tree = self._tuned.get(tkey)
        if tree is None:
            from repro.pim import autotune as _at

            if self.tune_cache is None:
                self.tune_cache = _at.as_cache(self._tuning_cache_arg)
            bits = parse_precision(precision)
            tree = _at.tune_tree(
                self._packed[(model, precision)], m_hint=n, a_bits=bits[1],
                backends=_at.default_backends(self.mesh),
                mode=self.autotune if self.autotune != "off" else "cost",
                cache=self.tune_cache, conv_m_hint=n * h * w)
            self._tuned[tkey] = tree
        return tree

    @property
    def _transient(self) -> bool:
        return self.faults is not None and self.faults.transient

    def _fwd_fn(self, model: str, precision: str | None, bucket: int,
                params=None):
        # Tuned trees differ from the base packed tree only in static
        # TuneDecision metadata, but that metadata IS part of the treedef —
        # key the compiled program (and build its in_shardings) from the
        # actual tree being dispatched so decisions recompile cleanly.
        # The untuned path keeps the historical 3-tuple key (one compile
        # per (model, precision, bucket)); tuned trees append their treedef.
        key = (model, precision, bucket)
        if params is not None:
            key = key + (jax.tree_util.tree_structure(params),)
        fn = self._fwd.get(key)
        if fn is None:
            module, _ = self._models[model]
            cfg = self._cfg(precision)
            faulty = cfg is not None and self._transient
            kw = {}
            if self.mesh is not None:
                from repro.distributed import sharding as _sh

                self._packed_params(model, precision)  # ensure sharding tree
                if cfg is None:
                    # Float reference path: fully replicated. CPU float convs
                    # are not bit-stable across batch shapes, so sharding the
                    # batch would break the bit-identity contract; the
                    # quantized deployment (exact integer core) is what
                    # shards chips x banks.
                    batch_sh = logits_sh = _sh.replicated(self.mesh)
                else:
                    batch_sh = _sh.serve_cnn_batch_sharding(self.mesh, bucket)
                    logits_sh = _sh.serve_cnn_logits_sharding(self.mesh,
                                                              bucket)
                p_sh = self._param_sh[(model, precision)]
                if params is not None:
                    # Mirror the committed shardings onto the dispatched
                    # tree's structure (identical leaves, tuned treedef).
                    p_sh = _sh.serve_cnn_param_shardings(
                        params, self.mesh, quantized=cfg is not None)
                in_sh = (p_sh, batch_sh)
                if faulty:
                    in_sh = in_sh + (_sh.replicated(self.mesh),)
                kw = dict(in_shardings=in_sh, out_shardings=logits_sh)
            if faulty:
                impl = partial(self._fwd_impl_faulty, module.apply, cfg,
                               self.faults)
            else:
                impl = partial(self._fwd_impl, module.apply, cfg)
            fn = jax.jit(impl, donate_argnums=(1,), **kw)
            self._fwd[key] = fn
        return fn

    @staticmethod
    def _fwd_impl(apply_fn, cfg, params, batch):
        return apply_fn(params, batch, cfg=cfg)

    @staticmethod
    def _fwd_impl_faulty(apply_fn, cfg, faults, params, batch, key):
        """Quantized forward with transient read disturb armed: every
        bit-serial weight read inside the trace draws its flip field from
        ``key`` (same scoped-context mechanism as ``ServeEngine._step_core``,
        so fused and im2col conv paths disturb identically)."""
        from repro.pim.faults import read_disturb_scope

        with read_disturb_scope(faults, key):
            return apply_fn(params, batch, cfg=cfg)

    def _act_gather_bound(self, params, bucket: int, h: int, w: int) -> int:
        """Largest legal all-gather in a quantized bucket forward: one
        activation map at the widest conv channel count (the paper's
        transfer phase redistributes activations between bank-split convs;
        nothing patch-matrix- or weight-sized may cross shards)."""
        from repro.core.packed import PackedConvWeight

        cmax = 1
        for leaf in jax.tree_util.tree_leaves(
                params, is_leaf=lambda x: isinstance(x, PackedConvWeight)):
            if isinstance(leaf, PackedConvWeight):
                _, _, c, o = leaf.kernel_shape
                cmax = max(cmax, int(c), int(o))
        return 4 * bucket * h * w * cmax

    def hot_paths(self, shapes=None):
        """Declare every dispatched bucket forward for the lint gate.

        ``shapes`` optionally supplies/overrides image shapes as
        ``{(model, precision, bucket): (h, w, c)}`` for callers that lint
        before any dispatch. Quantized mesh forwards budget their gathers
        at one widest-channel activation map; float forwards are fully
        replicated (zero gathers). The donated image batch is a
        free-the-buffer donation (it cannot alias the smaller logits), so
        no aliasing is demanded of it."""
        from functools import partial as _partial

        from repro import analysis as _an

        merged = dict(self._hot_shapes)
        merged.update(shapes or {})
        out = []
        for (model, precision, bucket), (h, w, c) in sorted(
                merged.items(), key=str):
            quantized = parse_precision(precision) is not None
            params = self._packed_params(model, precision)
            tuned = quantized and self.autotune != "off"
            if tuned:
                params = self._tuned_params(model, precision,
                                            (bucket, h, w, c))
            fn = self._fwd_fn(model, precision, bucket,
                              params if tuned else None)
            args = (params, jax.ShapeDtypeStruct((bucket, h, w, c),
                                                 jnp.float32))
            if quantized and self._transient:
                args = args + (jax.random.PRNGKey(0),)
            if self.mesh is None:
                gather_cap = None
            elif quantized:
                gather_cap = self._act_gather_bound(params, bucket, h, w)
            else:
                gather_cap = 0   # float path: fully replicated
            budget = _an.Budget(collectives=(("all-to-all", 0),),
                                max_gather_bytes=gather_cap,
                                m_hint=bucket,
                                pallas_ok=self.mesh is None)
            out.append(_an.HotPath(
                f"cnn.fwd[{model},{precision or 'float'},b={bucket}]",
                "cnn", budget, [_an.Program("fwd", fn, args)],
                context=_partial(self._activate, quantized)))
        return out

    def close(self):
        """Engine teardown: deregister from the lint gate and reset the
        tuning cache (see ServeEngine.close)."""
        from repro import analysis as _analysis
        _analysis.unregister(self)
        if self.tune_cache is not None:
            self.tune_cache.reset()

    # -- public API ----------------------------------------------------------

    def submit(self, req: VisionRequest):
        if req.model not in self._models:
            raise ValueError(f"unknown model {req.model!r} "
                             f"(registered: {sorted(self._models)})")
        # Validate at admission, not dispatch, and canonicalize the float
        # spellings so "float"/"fp32"/None requests share one cohort.
        if parse_precision(req.precision) is None:
            req.precision = None
        self.queue.append(req)

    def cancel(self, rid: int) -> bool:
        """Remove a queued request (deadline expiry / caller cancel). Vision
        dispatches are atomic — a bucket in flight has no mid-generation
        state to release — so cancellation is queue surgery only."""
        for i, r in enumerate(self.queue):
            if r.rid == rid:
                del self.queue[i]
                return True
        return False

    @property
    def n_free_slots(self) -> int:
        """Admission headroom the gateway fills before the next dispatch:
        the engine buckets at most ``max_batch`` per step, so the gateway
        keeps at most one bucket's worth staged in the engine queue."""
        return max(0, self.max_batch - len(self.queue))

    def degrade_cohort(self, model: str, precision: str | None) -> bool:
        """Move a (model, precision) cohort to the float fallback path —
        the watchdog's budget-spent action, exposed as a lever for the
        gateway's degradation ladder. Returns True if newly degraded."""
        mkey = (model, precision)
        if precision is None or mkey in self._degraded:
            return False
        self._degraded.add(mkey)
        self.health["degraded"].append(mkey)
        return True

    def restore_cohort(self, model: str, precision: str | None) -> bool:
        """Reverse :meth:`degrade_cohort` once load/fault pressure drops
        (the health log keeps the transition history). Returns True if the
        cohort was degraded."""
        mkey = (model, precision)
        if mkey not in self._degraded:
            return False
        self._degraded.discard(mkey)
        return True

    def _group_key(self, req: VisionRequest):
        return (req.model, req.precision, np.asarray(req.image).shape)

    def step(self) -> list:
        """Dispatch one micro-batch bucket; returns its completions.

        The queue head picks the (model, precision, shape) cohort; the
        bucket is the largest power of two ≤ min(cohort, max_batch).
        """
        if not self.queue:
            return []
        key = self._group_key(self.queue[0])
        # Two O(Q) passes, no per-request deque.remove: size the cohort,
        # then split taken / kept preserving the queue order of the rest.
        m = 0
        for r in self.queue:
            if self._group_key(r) == key:
                m += 1
                if m == self.max_batch:
                    break
        bucket = 1 << (m.bit_length() - 1)
        group, kept = [], []
        for r in self.queue:
            if len(group) < bucket and self._group_key(r) == key:
                group.append(r)
            else:
                kept.append(r)
        self.queue = collections.deque(kept)
        model, precision, _ = key
        if (model, precision) in self._degraded:
            # Degraded cohort: serve on the float fallback path (completions
            # keep their original rids; only the numerics path changes).
            precision = None
        if self.watchdog is None and self.fault_injector is None:
            return self._dispatch(group, model, precision)
        return self._dispatch_supervised(group, model, precision)

    def _dispatch(self, group, model: str, precision: str | None) -> list:
        bucket = len(group)
        batch = jnp.asarray(
            np.stack([np.asarray(r.image, np.float32) for r in group]))
        self._hot_shapes[(model, precision, bucket)] = tuple(batch.shape[1:])
        params = self._packed_params(model, precision)
        quantized = parse_precision(precision) is not None
        if quantized and self.autotune != "off":
            params = self._tuned_params(model, precision, batch.shape)
        with self._activate(quantized), warnings.catch_warnings():
            # The donated image batch cannot alias the (much smaller) logits
            # output on every backend; the donation is still declared so
            # backends that can reuse the buffer do. Silence the known-benign
            # "not usable" notice instead of spamming every bucket.
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            fn = self._fwd_fn(
                model, precision, bucket,
                params if quantized and self.autotune != "off" else None)
            if quantized and self._transient:
                self._fault_key, dkey = jax.random.split(self._fault_key)
                logits = fn(params, batch, dkey)
            else:
                logits = fn(params, batch)
        logits = np.asarray(logits)
        return [
            VisionCompletion(rid=r.rid, logits=logits[i],
                             top1=int(logits[i].argmax()), batch=bucket)
            for i, r in enumerate(group)
        ]

    def _dispatch_supervised(self, group, model: str,
                             precision: str | None) -> list:
        """Supervised bucket dispatch (DESIGN.md §7): retry under backoff on
        injected faults / device errors / non-finite logits / blown deadline,
        attempting a checksum repair before each retry; once the failure
        budget is spent, degrade the cohort to the float path and re-serve.

        The group is held locally (already split off the queue), so a retry
        is a pure re-dispatch — no queue surgery, no duplicated completions.
        """
        wd = self._wd
        while True:
            try:
                # Monotonic: an NTP wall-clock step must not blow the
                # dispatch deadline and burn the failure budget spuriously.
                t0 = time.monotonic()
                if self.fault_injector is not None:
                    self.fault_injector(self.health["dispatches"])
                out = self._dispatch(group, model, precision)
                dt = time.monotonic() - t0
                if wd.deadline_s is not None and dt > wd.deadline_s:
                    raise RuntimeError(
                        "vision dispatch exceeded deadline "
                        f"({dt:.3f}s > {wd.deadline_s:.3f}s)")
                if any(not np.isfinite(c.logits).all() for c in out):
                    raise RuntimeError("non-finite logits in vision dispatch")
                self.health["dispatches"] += 1
                self._policy.record_progress(self.health["dispatches"])
                return out
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                self.health["rollbacks"] += 1
                try:
                    wait = self._policy.on_failure()
                except RuntimeError:
                    # Failure budget spent. Float path failing, or degrade
                    # disabled: surface the error (orchestrator restarts).
                    if precision is None or not wd.degrade:
                        raise
                    mkey = (model, precision)
                    self._degraded.add(mkey)
                    self.health["degraded"].append(mkey)
                    from repro.training.fault_tolerance import RestartPolicy

                    self._policy = RestartPolicy(wd.max_failures, wd.backoff_s)
                    print(f"[vision-watchdog] cohort {mkey} degraded to the "
                          f"float path after {wd.max_failures} failures",
                          flush=True)
                    return self._dispatch(group, model, None)
                fixed = self._repair(model, precision)
                if fixed:
                    self.health["repairs"] += 1
                    self.health["repaired_cols"] += fixed
                print(f"[vision-watchdog] dispatch failed ({e!r}); "
                      f"repaired {fixed} col(s), retrying in {wait:.3f}s",
                      flush=True)
                time.sleep(min(wait, 0.05))  # bounded for tests

    def run(self, max_steps: int = 10_000, strict: bool = False) -> list:
        """Drain the queue; returns all completions.

        If the step budget runs out with requests still queued, raise
        (``strict=True``) or emit a ``RuntimeWarning`` naming the stranded
        rids — silent drops are how serving bugs hide.
        """
        out = []
        for _ in range(max_steps):
            if not self.queue:
                return out
            out.extend(self.step())
        if self.queue:
            rids = [r.rid for r in self.queue]
            msg = (f"VisionEngine.run: {len(rids)} request(s) still queued "
                   f"after {max_steps} steps (rids {rids[:8]})")
            if strict:
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning)
        return out
