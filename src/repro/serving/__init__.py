from .engine import Completion, Request, ServeEngine
from .sampler import SamplerConfig, sample

__all__ = ["Completion", "Request", "SamplerConfig", "ServeEngine", "sample"]
