from .engine import Completion, Request, ServeEngine
from .gateway import (DeadlineExceeded, Gateway, GatewayConfig, Ring,
                      ShedError, TokenStream, VisionTicket)
from .sampler import SamplerConfig, sample
from .vision import VisionCompletion, VisionEngine, VisionRequest, parse_precision

__all__ = [
    "Completion", "Request", "SamplerConfig", "ServeEngine", "sample",
    "VisionCompletion", "VisionEngine", "VisionRequest", "parse_precision",
    "Gateway", "GatewayConfig", "Ring", "ShedError", "DeadlineExceeded",
    "TokenStream", "VisionTicket",
]
