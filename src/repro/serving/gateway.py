"""Overload-safe asyncio serving gateway (DESIGN.md §8).

The engines (:class:`~repro.serving.engine.ServeEngine`,
:class:`~repro.serving.vision.VisionEngine`) are fast, donation-clean hot
loops fed by a bare in-process deque — no admission limits, no deadlines,
no behavior under overload. This module is the serving front line in front
of them, built so the donated jitted loops stay saturated while the system
degrades *gracefully* instead of falling over:

  * **Worker threads per engine.** Each engine is driven on its own worker
    thread; the asyncio event loop only touches bounded queues and
    ``asyncio.Queue`` token streams (fed via ``call_soon_threadsafe``), so
    a jitted dispatch never blocks the loop and a slow caller never blocks
    the grid.
  * **Bounded per-tenant queues + weighted-fair admission.** Every tenant
    gets a bounded FIFO; admission into free grid slots picks tenants by
    stride scheduling (virtual pass times advance by 1/weight), so a
    weight-2 tenant gets 2× the admissions of a weight-1 tenant under
    saturation and an idle tenant's unused share is redistributed. The
    engines' own internal queues are kept empty (LM) or at most one bucket
    deep (vision): the gateway queues are the only place requests wait, so
    every shedding decision happens in one place.
  * **Deadline propagation.** ``deadline_ms`` (per request, or the config
    default) starts at submission. Expired requests are cancelled while
    queued *and* mid-generation — the worker calls ``engine.cancel`` and
    the slot is released at the next token boundary through the same
    slot-free path a natural completion takes.
  * **Backpressure + load shedding.** A full tenant queue (or a shed tier)
    rejects at submission with :class:`ShedError` carrying a retry-after
    hint computed from the observed service rate — never silent unbounded
    growth. Queue depth is bounded by construction.
  * **Graceful degradation tiers.** Sustained overload walks a ladder, one
    tier per sustained-hold period, each transition logged and reversed
    when load drops: tier 1 shrinks the LM engine's ``drain_steps`` (a
    freed slot is re-admitted at the next token boundary instead of after
    a multi-step drain); tier 2 re-deploys to a cheaper precision via the
    PR 5 re-prepack machinery (``ServeEngine.redeploy`` /
    ``VisionEngine.degrade_cohort``) when configured; tier 3 sheds the
    lowest-priority tenants outright.
  * **Live telemetry.** Fixed-size ring buffers (the rolling-window logging
    idiom) for queue depth, TTFT (submit- and admission-referenced), TPOT,
    and a completion window for tokens/s + per-tenant goodput; ``stats()``
    returns a consistent snapshot with p50/p95/p99 percentiles, shed
    counters by reason, the degradation tier, and the transition log.

Numerics: the gateway adds zero. Admission order only picks *which* slot a
request lands in, and slots are isolated (tested since PR 2/3), so an
admitted request's token stream is bit-identical to the same request on an
unloaded engine — asserted under 2× overload in benchmarks/serve_bench.py.

Thread-ownership rule (machine-checked by ``repro.analysis.threads``, see
tests/test_analysis.py): the engines referenced by ``self._lm`` /
``self._vision`` are **owned by their worker threads**. Code reachable from
the event-loop entry points (``submit_lm``/``submit_vision``/``start``/
``stop``/``drain``/``stats``/``__aenter__``/``__aexit__``) must not call
engine methods or assign engine attributes — the only loop-side engine
access allowed is the read-only ``validate``/``n_free_slots`` pair used at
admission. Everything else (submit/step/cancel/redeploy/drain_steps
mutation, the degradation-tier actions) happens on the worker, which is
also the only side that touches jax. Handing the engine *object* around
(thread targets, ``_guard`` wrappers) is fine; calling into it from the
loop is not. The AST lint walks ``self.<method>()`` call edges from the
loop roots and flags any engine call or store outside the allowlist, so a
refactor that accidentally moves engine work onto the loop fails CI.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import itertools
import threading
import time

import numpy as np

from .engine import Request, ServeEngine
from .vision import VisionEngine, VisionRequest

_END = object()          # token-stream sentinel

# Shed reasons (ShedError.reason / stats()["shed"] keys).
SHED_QUEUE_FULL = "queue_full"
SHED_OVERLOAD = "overload"       # tier-3: tenant priority shed
SHED_EXPIRED = "expired"         # deadline passed (queued or mid-generation)


class ShedError(RuntimeError):
    """Request rejected at admission; retry after ``retry_after_s``."""

    def __init__(self, reason: str, retry_after_s: float):
        super().__init__(f"request shed ({reason}); "
                         f"retry after {retry_after_s:.3f}s")
        self.reason = reason
        self.retry_after_s = retry_after_s


class DeadlineExceeded(RuntimeError):
    """The request's ``deadline_ms`` passed before completion."""


class Ring:
    """Fixed-size float ring buffer with percentile snapshots.

    The telemetry backbone: O(1) push, O(size) snapshot, constant memory —
    a long-running gateway never grows its metrics state.
    """

    def __init__(self, size: int = 512):
        self._buf = np.zeros(size, np.float64)
        self._n = 0            # total pushes (monotonic)
        self._size = size

    def push(self, v: float):
        self._buf[self._n % self._size] = v
        self._n += 1

    def __len__(self):
        return min(self._n, self._size)

    def values(self) -> np.ndarray:
        return self._buf[:len(self)].copy()

    def percentiles(self, qs=(50, 95, 99)) -> dict:
        if not len(self):
            return {f"p{q}": None for q in qs}
        v = self.values()
        return {f"p{q}": float(np.percentile(v, q)) for q in qs}


@dataclasses.dataclass
class GatewayConfig:
    """Admission, deadline, shedding and degradation knobs."""

    queue_depth: int = 32                  # per-tenant bound (per engine)
    tenant_weights: dict = dataclasses.field(default_factory=dict)
    tenant_priority: dict = dataclasses.field(default_factory=dict)
    default_deadline_ms: float | None = None
    telemetry_window: int = 512            # ring size / completion window
    # Degradation ladder: escalate one tier per ``tier_hold_s`` of total
    # queue fullness >= ``overload_enter``; de-escalate one tier per hold
    # period of fullness <= ``overload_exit`` (hysteresis band between).
    overload_enter: float = 0.75
    overload_exit: float = 0.25
    tier_hold_s: float = 0.25
    # Admissions per worker iteration: each admission costs a prefill
    # before the group's next decode, so an unbounded burst makes the
    # first-popped request wait behind max_batch-1 prefills for its first
    # token. Pacing bounds that group to admit_burst (waiting requests
    # accrue bounded *queue* time instead, which deadlines/shedding govern).
    admit_burst: int = 2
    degraded_drain_steps: int = 1          # tier-1 lever (LM)
    degrade_precision: bool = False        # tier-2 lever: re-prepack cheaper
    poll_interval_s: float = 0.002         # idle worker wait
    retry_after_floor_s: float = 0.01


class _Handle:
    """Per-request gateway state, shared worker-thread <-> event-loop.

    The worker only writes plain fields and feeds ``q`` via
    ``call_soon_threadsafe``; the event loop only reads.
    """

    __slots__ = ("rid", "tenant", "kind", "payload", "deadline_t", "loop",
                 "q", "status", "submit_t", "admit_t", "first_tok_t",
                 "last_tok_t", "done_t", "n_streamed", "tokens", "result")

    def __init__(self, loop, rid, tenant, kind, payload, deadline_t):
        self.rid, self.tenant, self.kind = rid, tenant, kind
        self.payload = payload               # Request | VisionRequest
        self.deadline_t = deadline_t         # monotonic seconds, or None
        self.loop = loop
        self.q: asyncio.Queue = asyncio.Queue()
        self.status = "queued"  # queued|running|done|expired|shed|error
        self.submit_t = time.monotonic()
        self.admit_t = self.first_tok_t = self.last_tok_t = self.done_t = None
        self.n_streamed = 0
        self.tokens: list = []
        self.result = None                   # VisionCompletion

    def expired(self, now: float) -> bool:
        return self.deadline_t is not None and now > self.deadline_t

    def push(self, item):
        """Thread-safe feed into the caller's stream."""
        try:
            self.loop.call_soon_threadsafe(self.q.put_nowait, item)
        except RuntimeError:
            pass   # loop closed mid-shutdown; caller is gone


class TokenStream:
    """Async iterator over one LM request's tokens.

    ``async for tok in stream`` yields ints as the grid produces them and
    raises :class:`DeadlineExceeded` if the request expires mid-generation
    (tokens streamed so far stay in ``stream.tokens``). ``await
    stream.result()`` drains to completion and returns the full list.
    """

    def __init__(self, handle: _Handle):
        self._h = handle

    rid = property(lambda self: self._h.rid)
    status = property(lambda self: self._h.status)
    tokens = property(lambda self: list(self._h.tokens))

    def __aiter__(self):
        return self

    async def __anext__(self):
        item = await self._h.q.get()
        if item is _END:
            raise StopAsyncIteration
        if isinstance(item, Exception):
            raise item
        return item

    async def result(self) -> list:
        async for _ in self:
            pass
        return self.tokens


class VisionTicket:
    """Awaitable handle for one vision request."""

    def __init__(self, handle: _Handle):
        self._h = handle

    rid = property(lambda self: self._h.rid)
    status = property(lambda self: self._h.status)

    async def result(self):
        """The :class:`VisionCompletion` (raises on deadline/engine error)."""
        item = await self._h.q.get()
        if isinstance(item, Exception):
            raise item
        return item


class _FairQueues:
    """Bounded per-tenant FIFOs drained by stride scheduling.

    Each tenant carries a virtual ``pass`` value advanced by
    ``1 / weight`` per admission; ``pop_next`` serves the non-empty tenant
    with the smallest pass. A newly active tenant starts at the current
    minimum pass so it neither starves others nor claims catch-up credit.
    All methods run under the gateway lock.
    """

    def __init__(self, cfg: GatewayConfig):
        self.cfg = cfg
        self.queues: dict[str, collections.deque] = {}
        self.pass_: dict[str, float] = {}

    def _weight(self, tenant: str) -> float:
        return max(float(self.cfg.tenant_weights.get(tenant, 1.0)), 1e-6)

    def depth(self, tenant: str) -> int:
        q = self.queues.get(tenant)
        return len(q) if q else 0

    def total(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def capacity(self) -> int:
        return max(1, len(self.queues)) * self.cfg.queue_depth

    def full(self, tenant: str) -> bool:
        return self.depth(tenant) >= self.cfg.queue_depth

    def push(self, h: _Handle):
        q = self.queues.get(h.tenant)
        if q is None:
            q = self.queues[h.tenant] = collections.deque()
            base = min(self.pass_.values()) if self.pass_ else 0.0
            self.pass_[h.tenant] = base
        q.append(h)

    def pop_next(self, now: float) -> _Handle | None:
        """Next admission by weighted fairness, skipping expired heads
        (expired handles are returned to the caller via ``cull``)."""
        live = [(self.pass_[t], t) for t, q in self.queues.items() if q]
        for _, t in sorted(live):
            q = self.queues[t]
            while q:
                h = q.popleft()
                if h.expired(now):
                    # Put back for cull() to resolve uniformly.
                    q.appendleft(h)
                    break
                self.pass_[t] += 1.0 / self._weight(t)
                return h
        return None

    def cull(self, now: float) -> list[_Handle]:
        """Remove and return every expired queued handle."""
        out = []
        for q in self.queues.values():
            keep = collections.deque()
            while q:
                h = q.popleft()
                (out if h.expired(now) else keep).append(h)
            q.extend(keep)
        return out

    def drop_tenants(self, tenants: set) -> list[_Handle]:
        """Tier-3 shed: empty the given tenants' queues."""
        out = []
        for t in tenants:
            q = self.queues.get(t)
            if q:
                out.extend(q)
                q.clear()
        return out


class Gateway:
    """Asyncio front line over a :class:`ServeEngine` and/or
    :class:`VisionEngine` (either may be None).

    Usage::

        gw = Gateway(lm=engine, vision=veng, cfg=GatewayConfig(...))
        gw.start()                      # needs a running event loop
        stream = await gw.submit_lm(prompt, max_new_tokens=32,
                                    tenant="acme", deadline_ms=500)
        async for tok in stream: ...
        ticket = await gw.submit_vision(image, model="resnet50")
        completion = await ticket.result()
        gw.stats()                      # telemetry snapshot
        await gw.drain(); gw.stop()

    Or ``async with Gateway(...) as gw:`` for start/stop bracketing.
    """

    def __init__(self, lm: ServeEngine | None = None,
                 vision: VisionEngine | None = None,
                 cfg: GatewayConfig | None = None):
        if lm is None and vision is None:
            raise ValueError("gateway needs at least one engine")
        self.cfg = cfg or GatewayConfig()
        self._lm, self._vision = lm, vision
        self._lock = threading.Lock()
        self._loop: asyncio.AbstractEventLoop | None = None
        self._stop_evt = threading.Event()
        self._threads: list[threading.Thread] = []
        self._rids = itertools.count(1_000_000)   # auto rids (caller may pass)
        self._lm_q = _FairQueues(self.cfg)
        self._vi_q = _FairQueues(self.cfg)
        self._wake = threading.Event()
        self._inflight: dict[int, _Handle] = {}   # rid -> handle (both kinds)
        self._errors: list[str] = []
        # Telemetry (rings + windowed completion log; all under _lock).
        w = self.cfg.telemetry_window
        self._ttft = Ring(w)           # submit -> first token, ms
        self._ttft_admit = Ring(w)     # admission -> first token, ms
        self._tpot = Ring(w)           # inter-token gap, ms
        self._depth_ring = Ring(w)     # sampled total queue depth
        self._completions = collections.deque(maxlen=w)  # (t, tenant, ntok)
        self._max_depth = 0
        self._submits = 0
        self._shed = collections.Counter()
        self._svc_rate = 0.0           # completions/s EWMA
        self._last_done_t: float | None = None
        # Degradation ladder state.
        self._tier = 0
        self._above_since: float | None = None
        self._below_since: float | None = None
        self._events = collections.deque(maxlen=64)
        self._orig_drain = lm.drain_steps if lm is not None else None
        self._orig_pim = (lm.cfg.pim if lm is not None else None)
        self._shed_tenants: set = set()

    # -- lifecycle -----------------------------------------------------------

    def start(self, loop: asyncio.AbstractEventLoop | None = None):
        """Start the worker threads. Must run inside (or be handed) the
        event loop that will consume the streams."""
        if self._threads:
            return
        self._loop = loop or asyncio.get_running_loop()
        self._stop_evt.clear()
        for eng, name, fn in ((self._lm, "lm", self._lm_worker),
                              (self._vision, "vision", self._vision_worker)):
            if eng is None:
                continue
            t = threading.Thread(target=self._guard(fn), name=f"gw-{name}",
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _guard(self, fn):
        """Fail loudly: a worker crash resolves every owned request with the
        error and surfaces it in stats()/drain() instead of hanging callers."""
        def run():
            try:
                fn()
            except BaseException as e:                     # noqa: BLE001
                msg = f"{threading.current_thread().name} died: {e!r}"
                with self._lock:
                    self._errors.append(msg)
                    stranded = ([h for q in (self._lm_q, self._vi_q)
                                 for dq in q.queues.values() for h in dq]
                                + list(self._inflight.values()))
                    for q in (self._lm_q, self._vi_q):
                        for dq in q.queues.values():
                            dq.clear()
                    self._inflight.clear()
                for h in stranded:
                    h.status = "error"
                    h.push(RuntimeError(msg))
                    h.push(_END)
                print(f"[gateway] {msg}", flush=True)
        return run

    def stop(self):
        """Stop the workers (does not drain; see :meth:`drain`)."""
        self._stop_evt.set()
        self._wake.set()
        for t in self._threads:
            t.join(timeout=10)
        self._threads.clear()

    async def drain(self, timeout: float | None = None):
        """Wait until every queued + in-flight request resolves."""
        t0 = time.monotonic()
        while True:
            with self._lock:
                busy = (self._lm_q.total() + self._vi_q.total()
                        + len(self._inflight))
                if self._errors:
                    raise RuntimeError("; ".join(self._errors))
            if not busy:
                return
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"gateway drain: {busy} request(s) "
                                   f"unresolved after {timeout}s")
            await asyncio.sleep(self.cfg.poll_interval_s)

    async def __aenter__(self):
        self.start()
        return self

    async def __aexit__(self, *exc):
        self.stop()

    # -- submission (event-loop side) ---------------------------------------

    def _retry_after(self, queued_ahead: int) -> float:
        """Retry-after hint from the observed service rate: roughly when a
        queue slot should free. Floors early (cold EWMA) to a config bound."""
        rate = self._svc_rate
        if rate <= 0:
            return max(self.cfg.retry_after_floor_s, 0.1)
        return max(self.cfg.retry_after_floor_s, (queued_ahead + 1) / rate)

    def _admission_check(self, fq: _FairQueues, tenant: str):
        """Shed-at-submission policy; raises ShedError. Under _lock."""
        if self._tier >= 3 and tenant in self._shed_tenants:
            self._shed[SHED_OVERLOAD] += 1
            raise ShedError(SHED_OVERLOAD, self._retry_after(fq.total()))
        if fq.full(tenant):
            self._shed[SHED_QUEUE_FULL] += 1
            raise ShedError(SHED_QUEUE_FULL, self._retry_after(fq.depth(tenant)))

    def _register(self, fq: _FairQueues, h: _Handle):
        with self._lock:
            self._submits += 1
            self._admission_check(fq, h.tenant)
            fq.push(h)
            d = self._lm_q.total() + self._vi_q.total()
            self._max_depth = max(self._max_depth, d)
        self._wake.set()

    def _deadline_t(self, deadline_ms) -> float | None:
        if deadline_ms is None:
            deadline_ms = self.cfg.default_deadline_ms
        if deadline_ms is None:
            return None
        return time.monotonic() + deadline_ms / 1e3

    async def submit_lm(self, prompt, max_new_tokens: int = 32, *,
                        tenant: str = "default", deadline_ms: float | None = None,
                        eos_id: int = -1, rid: int | None = None) -> TokenStream:
        """Admit an LM request; returns a :class:`TokenStream` or raises
        :class:`ShedError` immediately (full queue / shed tier)."""
        if self._lm is None:
            raise ValueError("gateway has no LM engine")
        self._require_started()
        rid = next(self._rids) if rid is None else rid
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      deadline_ms=deadline_ms)
        # Validate on the caller's thread: a malformed request must raise
        # here, not inside the worker loop.
        self._lm.validate(req.prompt, req.max_new_tokens)
        h = _Handle(self._loop, rid, tenant, "lm", req,
                    self._deadline_t(deadline_ms))
        self._register(self._lm_q, h)
        return TokenStream(h)

    async def submit_vision(self, image, *, model: str = "resnet50",
                            precision: str | None = "<8:8>",
                            tenant: str = "default",
                            deadline_ms: float | None = None,
                            rid: int | None = None) -> VisionTicket:
        """Admit a vision request; returns a :class:`VisionTicket` or raises
        :class:`ShedError` immediately."""
        if self._vision is None:
            raise ValueError("gateway has no vision engine")
        self._require_started()
        rid = next(self._rids) if rid is None else rid
        req = VisionRequest(rid=rid, image=np.asarray(image, np.float32),
                            model=model, precision=precision,
                            deadline_ms=deadline_ms)
        if model not in self._vision._models:
            raise ValueError(f"unknown model {model!r}")
        h = _Handle(self._loop, rid, tenant, "vision", req,
                    self._deadline_t(deadline_ms))
        self._register(self._vi_q, h)
        return VisionTicket(h)

    def _require_started(self):
        if not self._threads:
            raise RuntimeError("gateway not started; call start() first")
        if self._errors:
            raise RuntimeError("; ".join(self._errors))

    # -- resolution helpers (worker side) -----------------------------------

    def _resolve_expired(self, h: _Handle):
        h.status = "expired"
        h.done_t = time.monotonic()
        with self._lock:
            self._shed[SHED_EXPIRED] += 1
        h.push(DeadlineExceeded(
            f"rid {h.rid}: deadline passed "
            f"({'mid-generation' if h.admit_t else 'queued'})"))
        h.push(_END)

    def _finish_lm(self, h: _Handle, tokens: list):
        now = time.monotonic()
        self._stream_lm(h, tokens, now)
        h.status = "done"
        h.done_t = now
        # Telemetry before the END sentinel: a caller awoken by END may
        # immediately drain() + stats(), and must see this completion.
        with self._lock:
            self._completions.append((now, h.tenant, len(tokens)))
            self._observe_service(now)
        h.push(_END)

    def _stream_lm(self, h: _Handle, tokens: list, now: float):
        """Forward tokens beyond what the caller has seen; telemetry on the
        producer side so event-loop scheduling doesn't skew TTFT/TPOT."""
        new = tokens[h.n_streamed:]
        if not new:
            return
        if h.first_tok_t is None:
            h.first_tok_t = now
            with self._lock:
                self._ttft.push((now - h.submit_t) * 1e3)
                if h.admit_t is not None:
                    self._ttft_admit.push((now - h.admit_t) * 1e3)
        elif h.last_tok_t is not None:
            # A drain dispatch emits n tokens in one host visit: spread the
            # gap over the batch for a per-token gap estimate.
            gap_ms = (now - h.last_tok_t) * 1e3 / len(new)
            with self._lock:
                for _ in new:
                    self._tpot.push(gap_ms)
        h.last_tok_t = now
        h.tokens.extend(int(t) for t in new)
        h.n_streamed = len(tokens)
        for t in new:
            h.push(int(t))

    def _observe_service(self, now: float):
        """Completion-rate EWMA feeding the retry-after hint. Under _lock."""
        if self._last_done_t is not None:
            dt = max(now - self._last_done_t, 1e-6)
            inst = 1.0 / dt
            a = 0.2
            self._svc_rate = (inst if self._svc_rate == 0.0
                              else a * inst + (1 - a) * self._svc_rate)
        self._last_done_t = now

    # -- degradation ladder --------------------------------------------------

    def _load_ratio(self) -> float:
        """Total queued / total bounded capacity, across both engines."""
        with self._lock:
            tot = self._lm_q.total() + self._vi_q.total()
            cap = 0
            if self._lm is not None:
                cap += self._lm_q.capacity()
            if self._vision is not None:
                cap += self._vi_q.capacity()
        return tot / max(cap, 1)

    def _ladder_tick(self, now: float):
        r = self._load_ratio()
        if r >= self.cfg.overload_enter:
            self._below_since = None
            if self._above_since is None:
                self._above_since = now
            elif (now - self._above_since >= self.cfg.tier_hold_s
                  and self._tier < 3):
                self._set_tier(self._tier + 1, f"load {r:.2f} sustained")
                self._above_since = now   # next tier needs a fresh hold
        elif r <= self.cfg.overload_exit:
            self._above_since = None
            if self._below_since is None:
                self._below_since = now
            elif (now - self._below_since >= self.cfg.tier_hold_s
                  and self._tier > 0):
                self._set_tier(self._tier - 1, f"load {r:.2f} dropped")
                self._below_since = now
        else:
            self._above_since = self._below_since = None

    def _set_tier(self, new: int, why: str):
        """Apply the levers between the current tier and ``new``. Each
        transition is logged and reversible; levers are idempotent."""
        old, self._tier = self._tier, new
        evt = {"t": time.monotonic(), "tier": new, "from": old, "why": why}
        self._events.append(evt)
        print(f"[gateway] degradation tier {old} -> {new} ({why})",
              flush=True)
        lm = self._lm
        # Tier 1: admission responsiveness — shrink the drain so freed
        # slots are refilled at the next token boundary.
        if lm is not None:
            lm.drain_steps = (max(1, self.cfg.degraded_drain_steps)
                              if new >= 1 else self._orig_drain)
        # Tier 2: cheaper precision via the PR 5 re-prepack machinery.
        if self.cfg.degrade_precision:
            self._apply_precision_tier(new >= 2)
        elif (new >= 2 and old < 2) or (new < 2 <= old):
            self._events.append({"t": time.monotonic(), "tier": new,
                                 "note": "precision tier disabled by config"})
        # Tier 3: shed lowest-priority tenants first.
        if new >= 3:
            dropped = self._enter_tenant_shed()
            for h in dropped:
                h.status = "shed"
                h.push(ShedError(SHED_OVERLOAD, self._retry_after(0)))
                h.push(_END)
        else:
            with self._lock:
                self._shed_tenants.clear()

    def _apply_precision_tier(self, on: bool):
        lm = self._lm
        if lm is not None and self._orig_pim is not None \
                and getattr(self._orig_pim, "enabled", False):
            try:
                if on and lm.cfg.pim.enabled:
                    lm.redeploy(dataclasses.replace(self._orig_pim,
                                                    enabled=False))
                elif not on and not lm.cfg.pim.enabled:
                    lm.redeploy(self._orig_pim)
            except RuntimeError as e:   # no masters kept: log, keep serving
                self._events.append({"t": time.monotonic(),
                                     "note": f"precision tier skipped: {e}"})
        if self._vision is not None:
            cohorts = [k for k in self._vision._packed if k[1] is not None]
            for model, prec in cohorts:
                if on:
                    self._vision.degrade_cohort(model, prec)
                else:
                    self._vision.restore_cohort(model, prec)

    def _enter_tenant_shed(self) -> list[_Handle]:
        """Pick the lowest-priority tenant cohort and drop its queues."""
        with self._lock:
            tenants = (set(self._lm_q.queues) | set(self._vi_q.queues)
                       | set(self.cfg.tenant_priority))
            if not tenants:
                return []
            prio = {t: self.cfg.tenant_priority.get(t, 0) for t in tenants}
            lowest = min(prio.values())
            shed = {t for t, p in prio.items() if p == lowest}
            if len(shed) == len(tenants):   # never shed everyone
                shed = set()
            self._shed_tenants = shed
            dropped = (self._lm_q.drop_tenants(shed)
                       + self._vi_q.drop_tenants(shed))
            self._shed[SHED_OVERLOAD] += len(dropped)
        return dropped

    # -- workers -------------------------------------------------------------

    def _cull_and_cancel(self, eng, fq: _FairQueues, now: float):
        """Deadline enforcement: expired queued handles resolve now; expired
        in-flight handles are cancelled in the engine (slot released at the
        next token boundary) and resolve immediately."""
        with self._lock:
            expired = fq.cull(now)
            for rid, h in list(self._inflight.items()):
                if h.kind == ("lm" if eng is self._lm else "vision") \
                        and h.expired(now):
                    eng.cancel(rid)
                    del self._inflight[rid]
                    expired.append(h)
        for h in expired:
            self._resolve_expired(h)

    def _sample_depth(self):
        with self._lock:
            d = self._lm_q.total() + self._vi_q.total()
            self._depth_ring.push(d)
            self._max_depth = max(self._max_depth, d)

    def _lm_worker(self):
        eng, fq = self._lm, self._lm_q
        while not self._stop_evt.is_set():
            now = time.monotonic()
            self._ladder_tick(now)
            self._cull_and_cancel(eng, fq, now)
            # Admit what the grid can take (paced by admit_burst); gateway
            # queues are the only waiting room.
            admitted = 0
            while eng.n_free_slots > 0 and admitted < self.cfg.admit_burst:
                with self._lock:
                    h = fq.pop_next(now)
                if h is None:
                    break
                h.admit_t = time.monotonic()
                h.status = "running"
                eng.submit(h.payload)
                self._inflight[h.rid] = h
                admitted += 1
            busy = bool(admitted) or any(r is not None for r in eng.slot_req) \
                or bool(eng.queue)
            if busy:
                # Drain length: multi-step drains amortize dispatch overhead
                # on an idle queue, but while gateway work is pending a long
                # drain delays the refill of slots that free mid-drain —
                # decode one step at a time, exactly the rule the engine
                # applies to its own queue. Tier >= 1 pins the short drain
                # even through transient empty-queue windows.
                with self._lock:
                    pending = fq.total() > 0
                base = (max(1, self.cfg.degraded_drain_steps)
                        if self._tier >= 1 else self._orig_drain)
                eng.drain_steps = 1 if pending else base
                done = eng.step()
                now = time.monotonic()
                for i, r in enumerate(eng.slot_req):
                    if r is not None:
                        h = self._inflight.get(r.rid)
                        if h is not None:
                            self._stream_lm(h, eng.slot_out[i], now)
                for c in done:
                    h = self._inflight.pop(c.rid, None)
                    if h is not None:
                        self._finish_lm(h, c.tokens)
            else:
                self._wake.wait(self.cfg.poll_interval_s)
                self._wake.clear()
            self._sample_depth()

    def _vision_worker(self):
        eng, fq = self._vision, self._vi_q
        while not self._stop_evt.is_set():
            now = time.monotonic()
            if self._lm is None:      # otherwise the LM worker ticks it
                self._ladder_tick(now)
            self._cull_and_cancel(eng, fq, now)
            admitted = False
            while eng.n_free_slots > 0:
                with self._lock:
                    h = fq.pop_next(now)
                if h is None:
                    break
                h.admit_t = time.monotonic()
                h.status = "running"
                eng.submit(h.payload)
                self._inflight[h.rid] = h
                admitted = True
            if admitted or eng.queue:
                done = eng.step()
                now = time.monotonic()
                for c in done:
                    h = self._inflight.pop(c.rid, None)
                    if h is None:
                        continue
                    h.status = "done"
                    h.done_t = h.first_tok_t = now
                    h.result = c
                    with self._lock:
                        self._ttft.push((now - h.submit_t) * 1e3)
                        if h.admit_t is not None:
                            self._ttft_admit.push((now - h.admit_t) * 1e3)
                        self._completions.append((now, h.tenant, 1))
                        self._observe_service(now)
                    h.push(c)
            else:
                self._wake.wait(self.cfg.poll_interval_s)
                self._wake.clear()
            self._sample_depth()

    # -- telemetry -----------------------------------------------------------

    def stats(self) -> dict:
        """Consistent snapshot of the live telemetry."""
        now = time.monotonic()
        with self._lock:
            comp = list(self._completions)
            window_tok = sum(n for _, _, n in comp)
            span = (now - comp[0][0]) if comp else 0.0
            by_tenant: dict = {}
            for t_, tenant, n in comp:
                by_tenant[tenant] = by_tenant.get(tenant, 0) + n
            goodput = {t: round(n / span, 2) if span > 0 else None
                       for t, n in sorted(by_tenant.items())}
            sheds = dict(self._shed)
            submits = self._submits
            depth_now = self._lm_q.total() + self._vi_q.total()
            snapshot = {
                "tier": self._tier,
                "queue": {
                    "depth": depth_now,
                    "max_depth": self._max_depth,
                    "bound": (self._lm_q.capacity()
                              if self._lm is not None else 0)
                    + (self._vi_q.capacity()
                       if self._vision is not None else 0),
                    "sampled": self._depth_ring.percentiles(),
                },
                "ttft_ms": self._ttft.percentiles(),
                "ttft_admit_ms": self._ttft_admit.percentiles(),
                "tpot_ms": self._tpot.percentiles(),
                "tok_s": round(window_tok / span, 2) if span > 0 else None,
                "svc_rate_req_s": round(self._svc_rate, 2),
                "submits": submits,
                "inflight": len(self._inflight),
                "shed": sheds,
                "shed_rate": (sum(sheds.values()) / submits
                              if submits else 0.0),
                "goodput_tok_s_by_tenant": goodput,
                "events": list(self._events),
                "errors": list(self._errors),
            }
        if self._lm is not None:
            snapshot["lm_health"] = dict(self._lm.health)
        if self._vision is not None:
            snapshot["vision_health"] = dict(self._vision.health)
        return snapshot
