"""ResNet50 (paper benchmark #3 and its breakdown model, Fig. 16)."""
from __future__ import annotations

import jax

from . import layers as L
from .specs import affine_spec, conv_spec, fc_spec, pool_spec

# (blocks, mid_channels) per stage; out = 4 * mid.
_STAGES = [(3, 64), (4, 128), (6, 256), (3, 512)]


def init(key, num_classes=1000, image=224):
    params = {"stem": L.init_conv(key, 7, 3, 64)}
    cin = 64
    k = jax.random.fold_in(key, 1)
    for s, (blocks, mid) in enumerate(_STAGES):
        cout = mid * 4
        for b in range(blocks):
            bk = jax.random.fold_in(k, s * 10 + b)
            blk = {
                "c1": L.init_conv(jax.random.fold_in(bk, 0), 1, cin, mid),
                "c2": L.init_conv(jax.random.fold_in(bk, 1), 3, mid, mid),
                "c3": L.init_conv(jax.random.fold_in(bk, 2), 1, mid, cout),
            }
            if b == 0:
                blk["proj"] = L.init_conv(jax.random.fold_in(bk, 3), 1, cin, cout)
            params[f"s{s}b{b}"] = blk
            cin = cout
    params["head"] = L.init_fc(jax.random.fold_in(key, 2), cin, num_classes)
    return params


def prepack(params, cfg):
    """Deployment: quantize+pack every weight once (program subarrays once)."""
    return L.prepack_params(params, cfg)


def _bottleneck(p, x, stride, cfg, train):
    y = L.conv_block(p["c1"], x, 1, 0, cfg=cfg, train=train)
    y = L.conv_block(p["c2"], y, stride, 1, cfg=cfg, train=train)
    y = L.conv_block(p["c3"], y, 1, 0, cfg=cfg, relu=False, train=train)
    if "proj" in p:
        x = L.conv_block(p["proj"], x, stride, 0, cfg=cfg, relu=False, train=train)
    return jax.nn.relu(x + y)


def apply(params, x, cfg=None, train=False):
    x = L.conv_block(params["stem"], x, stride=2, padding=3, cfg=cfg, train=train)
    x = L.max_pool(x, 3, 2)
    for s, (blocks, _mid) in enumerate(_STAGES):
        for b in range(blocks):
            x = _bottleneck(params[f"s{s}b{b}"], x, 2 if (b == 0 and s > 0) else 1,
                            cfg, train)
    x = L.avg_pool_global(x)
    return L.fc_block(params["head"], x, cfg=cfg, relu=False, train=train)


def layer_specs(batch=1, image=224, num_classes=1000):
    specs = []
    spec, h, _ = conv_spec("stem", batch, image, image, 3, 64, 7, 2, 3)
    specs += [spec, affine_spec("stem.bn", "bn", spec.out_elems),
              affine_spec("stem.q", "quant", spec.out_elems)]
    pspec, h, _ = pool_spec("stem.pool", batch, h + 1, h + 1, 64, 3, 2)
    specs.append(pspec)
    cin = 64
    for s, (blocks, mid) in enumerate(_STAGES):
        cout = mid * 4
        for b in range(blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            pre = f"s{s}b{b}"
            c1, h1, _ = conv_spec(f"{pre}.c1", batch, h, h, cin, mid, 1, 1, 0)
            c2, h2, _ = conv_spec(f"{pre}.c2", batch, h1, h1, mid, mid, 3, stride, 1)
            c3, h3, _ = conv_spec(f"{pre}.c3", batch, h2, h2, mid, cout, 1, 1, 0)
            for c in (c1, c2, c3):
                specs += [c, affine_spec(f"{c.name}.bn", "bn", c.out_elems),
                          affine_spec(f"{c.name}.q", "quant", c.out_elems)]
            if b == 0:
                pj, _, _ = conv_spec(f"{pre}.proj", batch, h, h, cin, cout, 1, stride, 0)
                specs += [pj, affine_spec(f"{pre}.proj.bn", "bn", pj.out_elems)]
            h = h3
            cin = cout
    specs.append(affine_spec("gap", "pool_avg", batch * cin))
    specs += [fc_spec("head", batch, cin, num_classes),
              affine_spec("head.q", "quant", batch * num_classes)]
    return specs
