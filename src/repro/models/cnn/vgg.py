"""VGG19 (paper benchmark #2)."""
from __future__ import annotations

import jax

from . import layers as L
from .specs import affine_spec, conv_spec, fc_spec, pool_spec

# VGG19: stage widths x conv counts, maxpool 2x2/2 after each stage.
_STAGES = [(64, 2), (128, 2), (256, 4), (512, 4), (512, 4)]
_FCS = [4096, 4096]


def _conv_names():
    return [f"conv{s + 1}_{i + 1}" for s, (_, reps) in enumerate(_STAGES) for i in range(reps)]


def init(key, num_classes=1000, image=224):
    names = _conv_names()
    keys = jax.random.split(key, len(names) + len(_FCS) + 1)
    params = {}
    cin, ki = 3, 0
    for s, (cout, reps) in enumerate(_STAGES):
        for i in range(reps):
            params[f"conv{s + 1}_{i + 1}"] = L.init_conv(keys[ki], 3, cin, cout)
            cin, ki = cout, ki + 1
    h = image // 2 ** len(_STAGES)
    dim = h * h * cin
    for j, width in enumerate(_FCS):
        params[f"fc{j + 1}"] = L.init_fc(keys[ki + j], dim, width)
        dim = width
    params["head"] = L.init_fc(keys[-1], dim, num_classes)
    return params


def prepack(params, cfg):
    """Deployment: quantize+pack every weight once (program subarrays once)."""
    return L.prepack_params(params, cfg)


def apply(params, x, cfg=None, train=False):
    for s, (cout, reps) in enumerate(_STAGES):
        for i in range(reps):
            x = L.conv_block(params[f"conv{s + 1}_{i + 1}"], x, stride=1,
                             padding=1, cfg=cfg, train=train)
        x = L.max_pool(x, 2, 2)
    x = x.reshape(x.shape[0], -1)
    for j in range(len(_FCS)):
        x = L.fc_block(params[f"fc{j + 1}"], x, cfg=cfg, train=train)
    return L.fc_block(params["head"], x, cfg=cfg, relu=False, train=train)


def layer_specs(batch=1, image=224, num_classes=1000):
    specs = []
    h, cin = image, 3
    for s, (cout, reps) in enumerate(_STAGES):
        for i in range(reps):
            name = f"conv{s + 1}_{i + 1}"
            spec, h, _ = conv_spec(name, batch, h, h, cin, cout, 3, 1, 1)
            specs += [spec,
                      affine_spec(f"{name}.bn", "bn", spec.out_elems),
                      affine_spec(f"{name}.q", "quant", spec.out_elems)]
            cin = cout
        pspec, h, _ = pool_spec(f"pool{s + 1}", batch, h, h, cout, 2, 2)
        specs.append(pspec)
    dim = h * h * cin
    for j, width in enumerate(_FCS + [num_classes]):
        nm = f"fc{j + 1}" if j < len(_FCS) else "head"
        specs += [fc_spec(nm, batch, dim, width),
                  affine_spec(f"{nm}.q", "quant", batch * width)]
        dim = width
    return specs
