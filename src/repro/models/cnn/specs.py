"""Layer-spec tables for the PIM architecture simulator.

Every compute layer is reduced to the GEMM the paper's subarrays execute
(convolution via the Fig. 8 sliding-window schedule == im2col):

    M = batch * OH * OW       output positions
    K = KH * KW * C_in        contraction length
    N = C_out                 output channels (bit-counter columns)

Pool/BN/quant layers carry element counts — the simulator charges their
in-memory addition / comparison / affine costs (paper §4.1-4.2).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GemmSpec:
    name: str
    kind: str            # conv | fc | pool_max | pool_avg | bn | quant | act
    m: int = 0           # GEMM rows (output positions)
    k: int = 0           # contraction length
    n: int = 0           # output channels
    out_elems: int = 0   # activation elements produced
    in_elems: int = 0    # activation elements consumed
    weight_elems: int = 0
    window: int = 0      # pooling window size (elements compared/summed)

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n


def conv_spec(name, batch, h, w, cin, cout, k, s, p) -> tuple[GemmSpec, int, int]:
    oh = (h + 2 * p - k) // s + 1
    ow = (w + 2 * p - k) // s + 1
    spec = GemmSpec(
        name=name, kind="conv", m=batch * oh * ow, k=k * k * cin, n=cout,
        out_elems=batch * oh * ow * cout, in_elems=batch * h * w * cin,
        weight_elems=k * k * cin * cout,
    )
    return spec, oh, ow


def pool_spec(name, batch, h, w, c, k, s, kind="pool_max") -> tuple[GemmSpec, int, int]:
    oh = (h - k) // s + 1
    ow = (w - k) // s + 1
    spec = GemmSpec(
        name=name, kind=kind, out_elems=batch * oh * ow * c,
        in_elems=batch * h * w * c, window=k * k,
    )
    return spec, oh, ow


def fc_spec(name, batch, cin, cout) -> GemmSpec:
    # The paper folds FC into 1x1 convolution (§4.2); same GEMM form.
    return GemmSpec(
        name=name, kind="fc", m=batch, k=cin, n=cout,
        out_elems=batch * cout, in_elems=batch * cin, weight_elems=cin * cout,
    )


def affine_spec(name, kind, elems) -> GemmSpec:
    return GemmSpec(name=name, kind=kind, out_elems=elems, in_elems=elems)


def model_specs(model: str, batch: int = 1, image: int = 224) -> list[GemmSpec]:
    from . import alexnet, resnet, vgg

    return {
        "alexnet": alexnet.layer_specs,
        "vgg19": vgg.layer_specs,
        "resnet50": resnet.layer_specs,
    }[model](batch=batch, image=image)


def total_macs(specs: list[GemmSpec]) -> int:
    return sum(s.macs for s in specs)
