"""AlexNet (paper benchmark #1)."""
from __future__ import annotations

import jax

from . import layers as L
from .specs import affine_spec, conv_spec, fc_spec, pool_spec

# (name, cout, k, stride, pad, pool_after(k, s) or None)
_CONVS = [
    ("conv1", 96, 11, 4, 2, (3, 2)),
    ("conv2", 256, 5, 1, 2, (3, 2)),
    ("conv3", 384, 3, 1, 1, None),
    ("conv4", 384, 3, 1, 1, None),
    ("conv5", 256, 3, 1, 1, (3, 2)),
]
_FCS = [4096, 4096]


def _feature_hw(image: int) -> int:
    h = image
    for _, _, k, s, p, pool in _CONVS:
        h = (h + 2 * p - k) // s + 1
        if pool:
            h = (h - pool[0]) // pool[1] + 1
    return h


def init(key, num_classes=1000, image=224):
    keys = jax.random.split(key, len(_CONVS) + len(_FCS) + 1)
    params = {}
    cin = 3
    for i, (name, cout, k, *_rest) in enumerate(_CONVS):
        params[name] = L.init_conv(keys[i], k, cin, cout)
        cin = cout
    h = _feature_hw(image)
    dim = h * h * cin
    for j, width in enumerate(_FCS):
        params[f"fc{j + 1}"] = L.init_fc(keys[len(_CONVS) + j], dim, width)
        dim = width
    params["head"] = L.init_fc(keys[-1], dim, num_classes)
    return params


def prepack(params, cfg):
    """Deployment: quantize+pack every weight once (program subarrays once)."""
    return L.prepack_params(params, cfg)


def apply(params, x, cfg=None, train=False):
    for name, _, _, s, p, pool in _CONVS:
        x = L.conv_block(params[name], x, stride=s, padding=p, cfg=cfg, train=train)
        if pool:
            x = L.max_pool(x, *pool)
    x = x.reshape(x.shape[0], -1)
    for j in range(len(_FCS)):
        x = L.fc_block(params[f"fc{j + 1}"], x, cfg=cfg, train=train)
    return L.fc_block(params["head"], x, cfg=cfg, relu=False, train=train)


def layer_specs(batch=1, image=224, num_classes=1000):
    specs = []
    h = image
    cin = 3
    for name, cout, k, s, p, pool in _CONVS:
        spec, h, _ = conv_spec(name, batch, h, h, cin, cout, k, s, p)
        specs += [spec,
                  affine_spec(f"{name}.bn", "bn", spec.out_elems),
                  affine_spec(f"{name}.q", "quant", spec.out_elems)]
        if pool:
            pspec, h, _ = pool_spec(f"{name}.pool", batch, h, h, cout, *pool)
            specs.append(pspec)
        cin = cout
    dim = h * h * cin
    for j, width in enumerate(_FCS + [num_classes]):
        nm = f"fc{j + 1}" if j < len(_FCS) else "head"
        specs += [fc_spec(nm, batch, dim, width),
                  affine_spec(f"{nm}.q", "quant", batch * width)]
        dim = width
    return specs
