"""CNN building blocks on top of the PIM layers (paper §4.2 pipeline).

Each block mirrors the paper's per-layer schedule: bit-serial convolution ->
in-memory BN affine (Eq. 3 folded) -> ReLU via MSB test -> re-quantize
(Eq. 2). In JAX the BN/ReLU/quant steps are ordinary elementwise ops; the
PIM *simulator* charges them at their in-memory cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (
    PIMQuantConfig,
    fold_batchnorm,
    pim_conv2d,
    pim_linear,
    prepack_conv2d,
    prepack_linear,
)


def prepack_params(params, cfg: PIMQuantConfig, faults=None):
    """Quantize + pack every conv/fc weight in a CNN param tree exactly once.

    The paper's deployment step: subarrays are programmed once, then every
    inference only streams activations. Replaces each ``"w"`` leaf with a
    :class:`PackedWeight`/:class:`PackedConvWeight`; biases and folded-BN
    params pass through untouched. ``conv_block``/``fc_block`` consume the
    prepacked tree unchanged.

    ``faults``: optional :class:`repro.pim.faults.FaultConfig` — corrupt the
    freshly programmed planes with persistent device faults (and, with
    ``faults.checksum``, repair flagged columns from spares) before the tree
    ships, modeling a real NAND-SPIN programming pass.
    """
    if cfg is None or not cfg.enabled:
        return params

    def walk(p):
        if isinstance(p, dict):
            out = {}
            for k, v in p.items():
                if k == "w" and hasattr(v, "ndim"):
                    out[k] = (prepack_conv2d(v, cfg) if v.ndim == 4
                              else prepack_linear(v, cfg))
                else:
                    out[k] = walk(v)
            return out
        return p

    packed = walk(params)
    if faults is not None:
        from repro.pim.faults import inject_tree

        packed, _ = inject_tree(packed, faults)
    return packed


def init_conv(key, k, cin, cout, bn=True):
    wkey, _ = jax.random.split(key)
    fan_in = k * k * cin
    p = {"w": jax.random.normal(wkey, (k, k, cin, cout)) * (2.0 / fan_in) ** 0.5}
    if bn:
        p.update(gamma=jnp.ones((cout,)), beta=jnp.zeros((cout,)),
                 mean=jnp.zeros((cout,)), var=jnp.ones((cout,)))
    else:
        p["b"] = jnp.zeros((cout,))
    return p


def init_fc(key, cin, cout):
    return {"w": jax.random.normal(key, (cin, cout)) * (2.0 / cin) ** 0.5,
            "b": jnp.zeros((cout,))}


def conv_block(p, x, stride=1, padding=0, cfg: PIMQuantConfig | None = None,
               relu=True, train=False):
    y = pim_conv2d(x, p["w"], p.get("b"), stride=stride, padding=padding,
                   cfg=cfg, train=train)
    if "gamma" in p:
        scale, bias = fold_batchnorm(p["gamma"], p["beta"], p["mean"], p["var"])
        y = y * scale + bias
    if relu:
        y = jax.nn.relu(y)  # paper: MSB test + conditional zero-write
    return y


def fc_block(p, x, cfg: PIMQuantConfig | None = None, relu=True, train=False):
    y = pim_linear(x, p["w"], p["b"], cfg=cfg, train=train)
    return jax.nn.relu(y) if relu else y


def max_pool(x, k, s):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), "VALID")


def avg_pool_global(x):
    return x.mean(axis=(1, 2))
