"""The paper's benchmark CNNs (AlexNet / VGG19 / ResNet50) in JAX.

Each model exposes:
  init(key, num_classes)   -> param pytree
  prepack(params, cfg)     -> same tree, weights quantized+packed once
  apply(params, x, cfg)    -> logits (cfg: PIMQuantConfig | None)
  layer_specs(hw, batch)   -> list[GemmSpec] consumed by the PIM simulator
"""
from . import alexnet, resnet, vgg
from .specs import GemmSpec, model_specs, total_macs

__all__ = ["alexnet", "vgg", "resnet", "GemmSpec", "model_specs", "total_macs"]
