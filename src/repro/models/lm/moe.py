"""Mixture-of-experts FFN with sort-based token dispatch (grok/phi3.5-moe).

Dispatch algorithm (the memory-sane one — no (T, E, C) one-hot tensors):

  1. router logits -> top-k (expert_id, gate) per token
  2. flatten the T*k assignments and sort them by expert id (stable, so
     intra-expert order is token order)
  3. rank-within-expert via a sorted-segment prefix sum; assignments with
     rank >= capacity are *dropped* (standard capacity-factor semantics)
  4. scatter surviving tokens into an (E, C, d) buffer, run the batched
     per-expert gated FFN as one einsum pair, gather back through the
     inverse permutation, combine with gate weights

Under GSPMD the (E, C, d) buffer shards expert-wise on the "model" mesh
axis (expert parallelism) when E divides the axis; otherwise the d_ff axis
shards (tensor parallelism inside every expert — grok's 8 experts on a
16-wide axis). ``repro.distributed.sharding`` applies those rules via
``with_sharding_constraint``; this module is mesh-agnostic.

The expert FFN itself has two executions sharing the dispatch/combine code
(so capacity/drop semantics are bit-identical between them):

  * float einsum — weights are raw (E, d, f) arrays; the training path and
    the serving float fallback (also the perf baseline ``moe_bench``
    measures the packed path against);
  * packed bit-serial — weights arrived as expert-stacked
    :class:`~repro.core.packed.PackedWeight` banks (``prepack_params``).
    The dispatched activations quantize *once*, before the sort/scatter,
    so dispatch moves int32 codes; each expert then runs
    ``int_matmul_prepacked`` + the Eq. 2 affine correction under
    ``jax.vmap`` over the expert bank (experts = the paper's chips, each
    contracting its own subarray image; DESIGN.md §11).

Aux losses follow the standard load-balancing recipe (mean gate * mean
assignment per expert) plus router z-loss; the aux dict additionally
carries the dropped-assignment fraction for engine telemetry.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packed import PackedWeight

from .config import ModelConfig

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}


def init_moe(cfg: ModelConfig, key):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * d**-0.5,
        "w_in": jax.random.normal(ks[1], (e, d, f), jnp.float32) * d**-0.5,
        "w_out": jax.random.normal(ks[2], (e, f, d), jnp.float32) * f**-0.5,
    }
    if cfg.act.endswith("gated"):
        p["w_gate"] = jax.random.normal(ks[3], (e, d, f), jnp.float32) * d**-0.5
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    mc = cfg.moe
    c = int(tokens * mc.top_k / mc.n_experts * mc.capacity_factor)
    return max(c + (-c) % 8, 8)  # sublane-align


def _packed_expert_ffn(p, cfg: ModelConfig, xg, slot, src_token,
                       g: int, e: int, cap: int, ep_ok: bool, act):
    """Expert FFN over prepacked bit-serial banks. Returns yb (g, e, cap, d).

    Fused quantize -> pack: the group activations calibrate and quantize
    once (per-tensor, Eq. 2) *before* dispatch, so the sort/scatter moves
    int32 codes rather than floats — the (E, C, d) buffer lands in code
    space and each expert's ``int_matmul_prepacked`` consumes it directly.
    Unfilled capacity slots hold code 0 (the dequantized minimum); their
    rows produce finite garbage that the combine's keep-mask never gathers.
    The hidden activations re-calibrate per expert for the w_out GEMM (the
    per-call activation-quantization idiom of ``pim_conv2d``).

    ``ep_ok``: expert-parallel serve layout — pin the per-expert operand
    stacks to the "model" axis so the only collectives GSPMD emits are the
    dispatch all-to-all (DP-sharded tokens -> E-sharded buffer) and the
    combine back. Packed weights only exist on the serving path, so these
    constraints never touch the (deliberately unconstrained) EP training
    einsums above.
    """
    from repro.core.bitserial import int_matmul_prepacked
    from repro.core.quantize import (affine_correction, calibrate_minmax,
                                     quantize)
    from repro.distributed import sharding as sh
    from jax.sharding import PartitionSpec as P

    pim = cfg.pim
    a_bits = pim.a_bits if pim is not None else 8
    backend = pim.backend if pim is not None else "int-direct"
    d = xg.shape[-1]
    f = p["w_in"].codes.shape[-1]
    mesh = sh.get_mesh()

    def ce(arr):  # expert dim on "model" (EP serve layout only)
        if mesh is None or not ep_ok:
            return arr
        return sh.constrain(arr, P("model", *(None,) * (arr.ndim - 1)))

    aq = calibrate_minmax(xg.astype(jnp.float32), a_bits)
    qxg = quantize(xg, aq)                                   # (g, tl, d) i32
    vals = jnp.take_along_axis(qxg, src_token[..., None], axis=1)
    gidx = jnp.arange(g)[:, None]
    buf = jnp.zeros((g, e * cap + 1, d), jnp.int32).at[gidx, slot].set(vals)
    qa = buf[:, :-1].reshape(g, e, cap, d)
    qa = ce(qa.transpose(1, 0, 2, 3).reshape(e, g * cap, d))  # (E, M, d)
    # Occupancy mask: unfilled slots zero after stage 1 (the float path's
    # empty rows), so they can't inflate the per-expert hidden calibration.
    filled = jnp.zeros((g, e * cap + 1), jnp.float32).at[gidx, slot].set(1.0)
    filled = filled[:, :-1].reshape(g, e, cap)
    filled = ce(filled.transpose(1, 0, 2).reshape(e, g * cap, 1))

    def stage1(w):
        def f1(qa_e, w_e):
            prod = int_matmul_prepacked(qa_e, w_e, a_bits, backend=backend)
            sa = qa_e.sum(-1, keepdims=True)
            return affine_correction(prod, sa, w_e.col_sums, d, aq, w_e.wq)
        return ce(jax.vmap(f1)(qa, w))

    h = stage1(p["w_in"])                                    # (E, M, f) f32
    h = act(stage1(p["w_gate"])) * h if "w_gate" in p else act(h)
    h = h * filled

    def f2(h_e, w_e):
        hq = calibrate_minmax(h_e, a_bits)
        qh = quantize(h_e, hq)
        prod = int_matmul_prepacked(qh, w_e, a_bits, backend=backend)
        sa = qh.sum(-1, keepdims=True)
        return affine_correction(prod, sa, w_e.col_sums, f, hq, w_e.wq)

    yb = ce(jax.vmap(f2)(h, p["w_out"]))                     # (E, M, d) f32
    return yb.reshape(e, g, cap, d).transpose(1, 0, 2, 3).astype(xg.dtype)


def moe_ffn(p, cfg: ModelConfig, x: jax.Array, train: bool = False):
    """x: (B, S, d) -> (out (B, S, d), aux dict).

    ``aux["loss"]`` is the balance + z loss scalar; ``aux["drop"]`` the
    fraction of top-k assignments dropped at capacity this call (routing
    overflow telemetry) and ``aux["layers"]`` a 1.0 layer counter so
    callers can average drop over depth.

    Group-batched sort dispatch: tokens route within their data-parallel
    shard group (own capacity — per-device capacity semantics of
    large-scale MoE). Every dispatch-stage tensor carries an explicit
    sharding constraint: the group dim pins to the DP axes and the expert
    FFN hidden dim to the TP axis, so the only collectives left are the
    FSDP weight all-gathers and the TP output all-reduce. (Unconstrained,
    GSPMD contracted the expert einsums over FSDP-sharded d and all-reduced
    multi-GB partial outputs — see EXPERIMENTS.md §Perf/grok.)"""
    from repro.distributed import sharding as sh
    from jax.sharding import PartitionSpec as P

    mc = cfg.moe
    b, s, d = x.shape
    t = b * s
    k, e = mc.top_k, mc.n_experts
    mesh = sh.get_mesh()
    g = 1
    dp = ()
    tp_ok = False
    if mesh is not None:
        dp = sh.dp_axes(mesh)
        dpn = sh.axis_size(mesh, *dp)
        if dpn > 1 and b % dpn == 0:
            g = dpn
        tp_ok = cfg.d_ff % sh.axis_size(mesh, "model") == 0

    tl = t // g
    cap = _capacity(tl, cfg)
    # Expert-parallel when E divides the TP axis (phi3.5: 16e/16) — expert
    # dim shards, dispatch becomes the classic EP all-to-all. Otherwise TP
    # inside each expert (grok: 8e/16) — hidden dim shards.
    ep_ok = mesh is not None and e % max(sh.axis_size(mesh, "model"), 1) == 0 \
        and sh.axis_size(mesh, "model") > 1

    def cg(arr, *spec):  # constrain with group dim on DP axes
        if mesh is None or g == 1:
            return arr
        return sh.constrain(arr, P(dp, *spec))

    xg = cg(x.reshape(g, tl, d), None, None)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (G, T_l, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- losses ----
    me = probs.mean(1)                                       # (G, E)
    one_hot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)  # (G,T_l,k,E)
    ce = one_hot.sum((1, 2)) / (tl * k)                      # (G, E)
    aux = mc.aux_loss * e * jnp.sum(me * ce, -1).mean()
    z = mc.router_z_loss * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- group-local sort dispatch ----
    flat_expert = expert_ids.reshape(g, tl * k)
    order = jnp.argsort(flat_expert, axis=-1, stable=True)
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=-1)
    group_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e), side="left"))(sorted_expert)
    rank = (jnp.arange(tl * k)[None]
            - jnp.take_along_axis(group_start, sorted_expert, axis=-1))
    keep = rank < cap
    slot = jnp.where(keep, sorted_expert * cap + rank, e * cap)
    src_token = order // k                                   # (G, T_l*k)

    gidx = jnp.arange(g)[:, None]
    act = _ACTS[cfg.act.split("_")[0]]

    if isinstance(p["w_in"], PackedWeight):
        # ---- packed bit-serial expert FFN (serving fast path) ----
        # Same slot/keep dispatch as below, but the scatter moves int32
        # codes and each expert contracts its prepacked subarray image.
        yb = _packed_expert_ffn(p, cfg, xg, slot, src_token,
                                g, e, cap, ep_ok, act)
    else:
        vals = jnp.take_along_axis(xg, src_token[..., None], axis=1)
        buf = jnp.zeros((g, e * cap + 1, d), x.dtype).at[gidx, slot].set(vals)
        buf = buf[:, :-1].reshape(g, e, cap, d)

        # ---- batched expert FFN (float einsum) ----
        # TP-expert case (E doesn't divide the TP axis, e.g. grok 8e/16): pin
        # buffers/weights so the hidden dim shards on TP and weights gather
        # their FSDP axis — unconstrained, GSPMD partial-reduced the (much
        # larger) activations over the data axis (§Perf/grok). EP case (E
        # divides, e.g. phi 16e/16): the at-rest expert sharding propagates
        # best UNconstrained — forcing the EP all-to-all through a dynamic
        # scatter regressed 4x (measured; see §Perf).
        tp = ("model",) if (tp_ok and not ep_ok) else (None,)

        def cw(wt, *spec):  # constrain an expert weight at use (TP case only)
            if mesh is None or ep_ok:
                return wt
            return sh.constrain(wt, P(*spec))

        def ca(arr, *spec):  # constrain an activation (TP case only)
            if ep_ok:
                return arr
            return cg(arr, *spec)

        buf = ca(buf, None, None, None)
        w_in = cw(p["w_in"], None, None, *tp)
        h = jnp.einsum("gecd,edf->gecf", buf, w_in.astype(x.dtype))
        h = ca(h, None, None, *tp)
        if "w_gate" in p:
            w_gate = cw(p["w_gate"], None, None, *tp)
            gt = jnp.einsum("gecd,edf->gecf", buf, w_gate.astype(x.dtype))
            h = act(ca(gt, None, None, *tp)) * h
        else:
            h = act(h)
        w_out = cw(p["w_out"], None, *tp, None)
        yb = jnp.einsum("gecf,efd->gecd", h, w_out.astype(x.dtype))
        yb = ca(yb, None, None, None)

    # ---- combine ----
    ybf = yb.reshape(g, e * cap, d)
    safe_slot = jnp.minimum(slot, e * cap - 1)
    y_sorted = jnp.take_along_axis(ybf, safe_slot[..., None], axis=1)
    y_sorted = jnp.where(keep[..., None], y_sorted, 0.0)
    w_sorted = jnp.take_along_axis(
        gate_vals.reshape(g, tl * k), order, axis=-1)[..., None].astype(x.dtype)
    out = jnp.zeros((g, tl, d), x.dtype).at[gidx, src_token].add(
        y_sorted * w_sorted)
    out = cg(out, None, None)
    drop = jnp.mean(1.0 - keep.astype(jnp.float32))
    return out.reshape(b, s, d), {"loss": aux + z, "drop": drop,
                                  "layers": jnp.ones((), jnp.float32)}
