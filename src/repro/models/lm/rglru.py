"""Real-Gated Linear Recurrent Unit block (RecurrentGemma / Griffin,
arXiv:2402.19427) — the "recurrent" third of the hybrid's 1:2 pattern.

Recurrence (per channel, f32):

    r_t = sigmoid(W_a x_t + b_a)              recurrence gate
    i_t = sigmoid(W_x x_t + b_x)              input gate
    a_t = exp(c * r_t * log_sigmoid(Lambda))  data-dependent decay, c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Prefill evaluates the whole sequence with ``jax.lax.associative_scan`` over
the affine maps (a_t, b_t) — O(S log S) depth, fully parallel across
(batch, channel) — and decode is the O(1) single-step update, which is what
makes the 500k-token shape runnable for this family. The block wraps the
recurrence with a width-4 causal depthwise conv and a GeLU gate branch
(Griffin's recurrent block), then projects back to d_model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pim_layers import pim_linear

from .config import ModelConfig

_C = 8.0


def init_rglru_block(cfg: ModelConfig, key):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    # Lambda init so that a ~ uniform(0.9, 0.999) at r = 1 (Griffin appendix).
    u = jax.random.uniform(ks[4], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1 of -log(a)/c
    return {
        "w_x": jax.random.normal(ks[0], (d, w), jnp.float32) * d**-0.5,
        "w_gate": jax.random.normal(ks[1], (d, w), jnp.float32) * d**-0.5,
        "conv": jax.random.normal(ks[5], (cfg.conv1d_width, w), jnp.float32) * 0.1,
        "w_a": jax.random.normal(ks[2], (w, w), jnp.float32) * w**-0.5,
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": jax.random.normal(ks[3], (w, w), jnp.float32) * w**-0.5,
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "w_out": jax.random.normal(jax.random.fold_in(key, 9), (w, d), jnp.float32) * w**-0.5,
    }


def _causal_conv(p_conv, x, state):
    """Depthwise causal conv, width K. x (B,S,W); state (B,K-1,W) | None."""
    kw = p_conv.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], kw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, W)
    y = sum(xp[:, i : i + x.shape[1]] * p_conv[i].astype(x.dtype) for i in range(kw))
    new_state = xp[:, -(kw - 1):] if kw > 1 else None
    return y, new_state


def _gates(p, x):
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_i"] + p["b_i"])
    log_a = _C * r * jax.nn.log_sigmoid(p["lam"])          # (B, S, W) or (B, W)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0)) * (i * xf)
    return a, b


def rglru_scan(p, x: jax.Array, h0: jax.Array | None = None):
    """Full-sequence recurrence via associative scan. x (B,S,W) -> (y, h_last)."""
    a, b = _gates(p, x)
    if h0 is not None:
        # Fold the carried state into the first step's offset.
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p, x: jax.Array, h_prev: jax.Array):
    """One decode step. x (B,W), h_prev (B,W) f32 -> (y, h)."""
    a, b = _gates(p, x)
    h = a * h_prev + b
    return h.astype(x.dtype), h


def rglru_block(p, cfg: ModelConfig, x: jax.Array, state: dict | None = None,
                train: bool = False):
    """Griffin recurrent block. x (B,S,d) -> (out (B,S,d), new_state|None)."""
    gate = jax.nn.gelu(pim_linear(x, p["w_gate"], cfg=cfg.pim, train=train))
    h_in = pim_linear(x, p["w_x"], cfg=cfg.pim, train=train)
    conv_state = state["conv"] if state is not None else None
    h_in, new_conv = _causal_conv(p["conv"], h_in, conv_state)
    if state is not None and x.shape[1] == 1:
        y, h_last = rglru_step(p, h_in[:, 0], state["h"])
        y = y[:, None]
    else:
        h0 = state["h"] if state is not None else None
        y, h_last = rglru_scan(p, h_in, h0)
    out = pim_linear(y * gate, p["w_out"], cfg=cfg.pim, train=train,
                     role="tp_in")
    new_state = {"conv": new_conv, "h": h_last} if state is not None else None
    return out, new_state
