"""Model configuration covering every assigned architecture family.

One dataclass describes dense, MoE, hybrid (RG-LRU + local attention),
attention-free (RWKV6), audio-backbone and VLM-backbone transformers. The
per-arch files in :mod:`repro.configs` instantiate it with the published
hyperparameters; reduced variants (``cfg.reduced()``) drive the CPU smoke
tests.

The paper's technique enters through ``pim``: any linear projection in the
model can execute through the bit-serial quantized pipeline
(:mod:`repro.core.pim_layers`), which is how the NAND-SPIN dataflow becomes
a first-class feature of an LM serving/training framework rather than a
CNN-only artifact. See DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

from repro.core.pim_layers import PIMQuantConfig

BlockKind = Literal["attn", "local_attn", "rglru", "rwkv", "cross_attn"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024

    # Attention variants
    qkv_bias: bool = False         # qwen1.5
    qk_norm: bool = False          # qwen3
    rope_theta: float = 10_000.0
    local_window: int = 0          # >0 -> sliding-window for local_attn blocks
    logits_softcap: float = 0.0    # grok-style tanh soft-capping (0 = off)
    attn_softcap: float = 0.0

    # Block schedule. Empty -> ["attn"] * n_layers. A pattern shorter than
    # n_layers tiles (recurrentgemma: ("rglru", "rglru", "local_attn")).
    block_pattern: tuple = ()

    # Mixture-of-experts (applies to every FFN when set)
    moe: MoEConfig | None = None

    # Hybrid / SSM substrate
    conv1d_width: int = 4          # temporal conv in RG-LRU blocks
    lru_width: int = 0             # 0 -> d_model
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 0            # >0: chunked-parallel WKV (perf path)

    # VLM: insert a cross-attention block every k self-attention layers.
    cross_attn_every: int = 0
    n_image_tokens: int = 0        # stub frontend sequence length

    # Audio backbone: inputs arrive as precomputed frame embeddings.
    embed_inputs: bool = True      # False -> (B, S, d_model) float inputs

    # Activation / norm flavor
    act: str = "silu_gated"        # silu_gated | gelu_gated | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    post_attn_norm: bool = False   # grok/ gemma style extra norms

    # Numerics
    dtype: str = "bfloat16"        # activations/params compute dtype
    param_dtype: str = "float32"   # master copy

    # The paper's technique (bit-serial quantized projections)
    pim: PIMQuantConfig | None = None
    # Eq.-2 quantization extended to serving state: int8 KV cache with
    # per-(token, head) scales folded into the attention einsums (the
    # dequantized cache is never materialized). Halves decode cache reads.
    kv_quant: bool = False

    # Training-time memory policy
    remat: str = "block"           # none | block | full
    loss_chunk: int = 0            # >0 -> chunked xent over seq (big vocabs)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    # -- derived -----------------------------------------------------------

    @property
    def blocks(self) -> tuple:
        """Per-layer block kinds, pattern tiled to n_layers."""
        pat = self.block_pattern or ("attn",)
        out = []
        i = 0
        while len(out) < self.n_layers:
            kind = pat[i % len(pat)]
            # VLM: cross-attn layers are *extra* layers interleaved every k.
            out.append(kind)
            i += 1
        if self.cross_attn_every:
            merged = []
            for j, k in enumerate(out):
                merged.append(k)
                if (j + 1) % self.cross_attn_every == 0:
                    merged.append("cross_attn")
            out = merged[: self.n_layers]
        return tuple(out)

    @property
    def attends_globally(self) -> bool:
        """True if any block is full (unwindowed) self-attention — such archs
        cannot run the 500k-token decode shape (quadratic KV)."""
        return any(b in ("attn", "cross_attn") for b in self.blocks) and not all(
            b in ("rglru", "rwkv", "local_attn", "cross_attn") for b in self.blocks
        )

    @property
    def recurrent(self) -> bool:
        return any(b in ("rglru", "rwkv") for b in self.blocks)

    def n_params(self) -> int:
        """Total parameter count (analytic; matches init exactly)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        hd = self.head_dim
        for kind in self.blocks:
            if kind in ("attn", "local_attn", "cross_attn"):
                qkv = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                if self.qkv_bias:
                    qkv += (self.n_heads + 2 * self.n_kv_heads) * hd
                total += qkv + self.n_heads * hd * d + d  # + pre-norm
                if self.qk_norm:
                    total += 2 * hd
                if self.post_attn_norm:
                    total += d
            elif kind == "rglru":
                w = self.lru_width or d
                total += d * 2 * w + self.conv1d_width * w  # in-proj x2 + conv
                total += 2 * w * w // 1 + w * 3  # gates (block-diag approx) + lru params
                total += w * d + d  # out proj + norm
            elif kind == "rwkv":
                total += d * d * 4 + d * 2  # r,k,v,g (time-mix)
                total += d * 64 * 2 + d * 2  # decay lora + token-shift mixes
                total += d * d + d  # output + ln
            # FFN for every block except pure rwkv (rwkv channel-mix differs)
            if kind == "rwkv":
                total += d * self.d_ff + self.d_ff * d + d  # channel-mix + ln
            elif kind in ("attn", "local_attn", "rglru"):
                gated = self.act.endswith("gated")
                per_ffn = d * self.d_ff * (3 if gated else 2)
                if self.moe:
                    total += self.moe.n_experts * per_ffn + d * self.moe.n_experts
                else:
                    total += per_ffn
                total += d  # pre-ffn norm
        total += d  # final norm
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.moe:
            return self.n_params()
        gated = self.act.endswith("gated")
        per_ffn = self.d_model * self.d_ff * (3 if gated else 2)
        n_ffn_blocks = sum(1 for b in self.blocks if b in ("attn", "local_attn", "rglru"))
        inactive = n_ffn_blocks * per_ffn * (self.moe.n_experts - self.moe.top_k)
        return self.n_params() - inactive

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 4 if not self.cross_attn_every else 6),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            lru_width=128 if self.lru_width else 0,
            rwkv_head_dim=32,
            n_image_tokens=16 if self.n_image_tokens else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            local_window=min(self.local_window, 64) if self.local_window else 0,
            loss_chunk=0,
            remat="none",
        )
        if self.moe:
            small["moe"] = dataclasses.replace(self.moe, n_experts=4, top_k=2)
        small.update(overrides)
        return dataclasses.replace(self, **small)
