"""Feed-forward blocks: gated (llama-style) and plain (musicgen-style).

Projections route through ``pim_linear``, so the paper's bit-serial
quantized execution applies to FFNs exactly as it does to attention — FFN
GEMMs are where most LM FLOPs live, i.e. where the NAND-SPIN technique pays.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pim_layers import pim_linear

from .config import ModelConfig

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}


def init_mlp(cfg: ModelConfig, key):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_in": jax.random.normal(ks[0], (d, f), jnp.float32) * d**-0.5,
        "w_out": jax.random.normal(ks[1], (f, d), jnp.float32) * f**-0.5,
    }
    if cfg.act.endswith("gated"):
        p["w_gate"] = jax.random.normal(ks[2], (d, f), jnp.float32) * d**-0.5
    return p


def mlp(p, cfg: ModelConfig, x: jax.Array, train: bool = False) -> jax.Array:
    act = _ACTS[cfg.act.split("_")[0]]
    h = pim_linear(x, p["w_in"], cfg=cfg.pim, train=train)
    if "w_gate" in p:
        g = pim_linear(x, p["w_gate"], cfg=cfg.pim, train=train)
        h = act(g) * h
    else:
        h = act(h)
    return pim_linear(h, p["w_out"], cfg=cfg.pim, train=train, role="tp_in")
