"""LM assembly: embed -> block schedule -> head, for all assigned families.

Layers execute through ``jax.lax.scan`` over the arch's *repeating unit*
(dense: one block; recurrentgemma: (rglru, rglru, local_attn); vision:
five self + one cross). Stacked-parameter scan keeps HLO size and compile
time flat in depth — essential when the dry-run compiles 100-layer models
on 512 host devices — and any remainder layers are unrolled after the scan.

Three entry points, shared by training, serving and the dry-run:

  ``forward(params, cfg, batch)``             -> logits (+ MoE aux loss)
  ``loss_fn(params, cfg, batch)``             -> scalar xent (chunked option)
  ``decode_step(params, cfg, tokens, state)`` -> (logits, new state)

``init(cfg, key)`` builds real parameters; ``abstract_params(cfg)`` is the
same tree as ShapeDtypeStructs (via ``jax.eval_shape``) for the dry-run,
and ``param_count(cfg)`` the exact parameter count derived from it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.pim_layers import pim_linear
from repro.distributed.sharding import constrain_batch

from . import attention as A
from . import cache as C
from . import mlp as M
from . import moe as MOE
from . import rglru as RG
from . import rwkv6 as RW
from .config import ModelConfig
from .norms import apply_norm, init_norm


# ---------------------------------------------------------------------------
# Repeating-unit detection
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig) -> tuple[tuple, int, tuple]:
    """blocks -> (unit, n_reps, remainder) maximizing scanned coverage."""
    blocks = cfg.blocks
    best = (blocks[:1], 1, blocks[1:])
    best_cov = 1
    for ln in range(1, min(len(blocks), 8) + 1):
        unit = blocks[:ln]
        reps = 0
        while blocks[reps * ln:(reps + 1) * ln] == unit:
            reps += 1
        cov = reps * ln
        if cov > best_cov or (cov == best_cov and ln < len(best[0])):
            best, best_cov = (unit, reps, blocks[reps * ln:]), cov
    return best


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------

def init_block(kind: str, cfg: ModelConfig, key):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p = {"norm1": init_norm(cfg.norm, d)}
    if kind in ("attn", "local_attn", "cross_attn"):
        p["attn"] = A.init_attention(cfg, ks[0], cross=(kind == "cross_attn"))
        if cfg.post_attn_norm:
            p["norm_post"] = init_norm(cfg.norm, d)
        p["norm2"] = init_norm(cfg.norm, d)
        p["ffn"] = MOE.init_moe(cfg, ks[1]) if cfg.moe else M.init_mlp(cfg, ks[1])
    elif kind == "rglru":
        p["rglru"] = RG.init_rglru_block(cfg, ks[0])
        p["norm2"] = init_norm(cfg.norm, d)
        p["ffn"] = MOE.init_moe(cfg, ks[1]) if cfg.moe else M.init_mlp(cfg, ks[1])
    elif kind == "rwkv":
        p["time_mix"] = RW.init_rwkv_block(cfg, ks[0])
        p["norm2"] = init_norm(cfg.norm, d)
        p["channel_mix"] = RW.init_rwkv_channel_mix(cfg, ks[1])
    else:
        raise ValueError(kind)
    return p


def _zero_aux():
    """Per-block aux accumulator: MoE balance loss plus routing telemetry
    (dropped-assignment fraction, summed over MoE layers with a layer count
    so the engine can report a mean). A dict of f32 scalars so it threads
    through ``lax.scan`` like the old bare scalar did."""
    z = jnp.zeros((), jnp.float32)
    return {"loss": z, "drop": z, "layers": z}


def apply_block(kind: str, p, cfg: ModelConfig, x, q_pos, state=None,
                cache_index=None, image_embeds=None, train=False):
    """Pre-norm residual block. Returns (x, new_state, aux dict)."""
    aux = _zero_aux()
    h = apply_norm(cfg.norm, p["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "local_attn", "cross_attn"):
        window = cfg.local_window if kind == "local_attn" else 0
        y, new_inner = A.attention(
            p["attn"], cfg, h, q_pos,
            kv_src=image_embeds if kind == "cross_attn" else None,
            cache=state, cache_index=cache_index,
            window=window, ring=(kind == "local_attn" and state is not None),
            train=train,
        )
        if cfg.post_attn_norm:
            y = apply_norm(cfg.norm, p["norm_post"], y, cfg.norm_eps)
        x = x + y
        h2 = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
        if cfg.moe:
            y2, aux = MOE.moe_ffn(p["ffn"], cfg, h2, train=train)
        else:
            y2 = M.mlp(p["ffn"], cfg, h2, train=train)
        x = x + y2
        return x, new_inner, aux
    if kind == "rglru":
        y, new_inner = RG.rglru_block(p["rglru"], cfg, h, state, train=train)
        x = x + y
        h2 = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
        if cfg.moe:
            y2, aux = MOE.moe_ffn(p["ffn"], cfg, h2, train=train)
        else:
            y2 = M.mlp(p["ffn"], cfg, h2, train=train)
        x = x + y2
        return x, new_inner, aux
    if kind == "rwkv":
        y, new_inner = RW.rwkv_time_mix(p["time_mix"], cfg, h, state, train=train)
        x = x + y
        h2 = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
        y2, new_inner2 = RW.rwkv_channel_mix(p["channel_mix"], cfg, h2, new_inner, train=train)
        x = x + y2
        return x, new_inner2, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key) -> dict:
    unit, reps, rest = layer_plan(cfg)
    ks = jax.random.split(key, 4)
    params: dict = {}
    if cfg.embed_inputs:
        params["embed"] = jax.random.normal(
            ks[0], (cfg.vocab, cfg.d_model), jnp.float32) * cfg.d_model**-0.5

    def unit_params(k):
        uks = jax.random.split(k, len(unit))
        return [init_block(kind, cfg, uk) for kind, uk in zip(unit, uks)]

    rep_keys = jax.random.split(ks[1], reps)
    stacked = [unit_params(k) for k in rep_keys]
    # list[rep][pos] -> list[pos] of stacked trees
    params["scan"] = [
        jax.tree.map(lambda *xs: jnp.stack(xs), *[s[i] for s in stacked])
        for i in range(len(unit))
    ]
    rest_keys = jax.random.split(ks[2], max(len(rest), 1))
    params["rest"] = [init_block(kind, cfg, k) for kind, k in zip(rest, rest_keys)]
    params["final_norm"] = init_norm(cfg.norm, cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            ks[3], (cfg.d_model, cfg.vocab), jnp.float32) * cfg.d_model**-0.5
    return params


def abstract_params(cfg: ModelConfig, dtype=None):
    """Parameter tree as ShapeDtypeStructs — no allocation (dry-run path).

    ``dtype`` casts matrix params to the compute dtype (as ``cast_params``
    would on real arrays)."""
    def build(k):
        p = init(cfg, k)
        return cast_params(p, dtype) if dtype is not None else p
    return jax.eval_shape(build, jax.random.PRNGKey(0))


def param_count(cfg: ModelConfig) -> int:
    import math

    return sum(math.prod(l.shape) for l in jax.tree.leaves(abstract_params(cfg)))


def cast_params(params, dtype):
    """Cast float params to the compute dtype (norm scales stay f32)."""
    def _cast(x):
        if x.dtype == jnp.float32 and x.ndim >= 2:
            return x.astype(dtype)
        return x
    return jax.tree.map(_cast, params)


# Projection leaves that route through pim_linear — the prepack targets.
# (embed / tied heads stay float: the embedding gather is not a GEMM.)
_PIM_PROJ_KEYS = frozenset({
    "wq", "wk", "wv", "wo",                      # attention
    "w_in", "w_out", "w_gate",                   # mlp / rglru
    "w_x",                                       # rglru input proj
    "w_r", "w_k", "w_v", "w_g", "w_o",           # rwkv6
    "head",                                      # untied lm head
})

# Expert-bank leaves inside a router-bearing dict — (E, d, f)-stacked, packed
# one vmap level deeper than the scan stack (the router itself stays float).
_MOE_EXPERT_KEYS = frozenset({"w_in", "w_out", "w_gate"})


def prepack_params(params, cfg, mesh=None, faults=None):
    """Quantize + pack every pim_linear projection weight exactly once.

    The serving-time analog of the paper's subarray programming: after this,
    repeated ``decode_step``/``prefill`` calls never re-calibrate, re-quantize
    or re-pack a weight. Scan-stacked leaves (R, K, N) prepack under ``vmap``
    so the layer scan slices per-rep :class:`PackedWeight` pytrees exactly as
    it slices raw arrays. MoE expert banks pack the same way, one ``vmap``
    level deeper: ``w_in``/``w_out``/``w_gate`` inside a router-bearing dict
    are (E, d, f) (or (R, E, d, f) scan-stacked) and prepack per expert, the
    layout ``moe_ffn`` contracts through ``int_matmul_prepacked`` under
    ``vmap`` (DESIGN.md §11). The ``router`` itself stays float: the top-k
    gate is tiny, runs in f32 by contract, and keeping it float makes the
    packed path's routing decisions bit-identical to the float reference.
    Left as floats otherwise: tied embeddings (the lm_head reuses the
    embedding matrix, whose primary role is the token gather).

    ``mesh``: additionally distribute the (packed or float) tree with the
    serving shardings — every projection's output dim, and for packed
    weights the PackedWeight planes/col_sums N dim, split across the mesh's
    "model" axis (the paper's banks; DESIGN.md §5). Applies whether or not
    ``cfg`` enables quantization, so the float serving path shards the same
    way.

    ``faults``: an optional :class:`repro.pim.faults.FaultConfig` — after
    packing, persistent device faults (stochastic writes, retention,
    stuck-at cells, dead subarrays) corrupt the packed planes, exactly as a
    real subarray-programming pass would; with ``faults.checksum`` armed,
    flagged columns repair from spares before the tree ships. Applied
    *before* ``maybe_shard`` so the corruption draws on global shapes —
    bit-identical on one device or the full serving mesh.
    """
    from repro.core.packed import prepack

    def maybe_shard(tree):
        if mesh is None:
            return tree
        from repro.distributed import sharding as sh

        return jax.device_put(tree, sh.serve_param_shardings(tree, mesh))

    if cfg is None or not getattr(cfg, "enabled", False):
        return maybe_shard(params)

    def pack_leaf(leaf):
        fn = functools.partial(prepack, w_bits=cfg.w_bits)
        for _ in range(leaf.ndim - 2):   # scan reps and/or expert stacks
            fn = jax.vmap(fn)
        return fn(leaf.astype(jnp.float32))

    def walk(p):
        if isinstance(p, dict):
            if "router" in p:            # MoE: pack experts, router stays f32
                return {k: (pack_leaf(v)
                            if (k in _MOE_EXPERT_KEYS and hasattr(v, "ndim")
                                and v.ndim in (3, 4)
                                and jnp.issubdtype(v.dtype, jnp.floating))
                            else v)
                        for k, v in p.items()}
            return {k: (pack_leaf(v)
                        if (k in _PIM_PROJ_KEYS and hasattr(v, "ndim")
                            and v.ndim in (2, 3)
                            and jnp.issubdtype(v.dtype, jnp.floating))
                        else walk(v))
                    for k, v in p.items()}
        if isinstance(p, (list, tuple)):
            return type(p)(walk(v) for v in p)
        return p

    packed = walk(params)
    if faults is not None:
        from repro.pim.faults import inject_tree

        packed, _ = inject_tree(packed, faults)
    return maybe_shard(packed)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    policy = (None if cfg.remat == "full"
              else jax.checkpoint_policies.save_only_these_names("decode_cache"))
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


def _run_blocks(params, cfg: ModelConfig, x, q_pos, states=None, cache_index=None,
                image_embeds=None, train=False):
    """Apply the full block schedule.

    ``states`` (decode/prefill): the stacked-state dict built by
    ``cache.init_model_state`` — scan-position states already carry the
    (n_reps,) axis, so the layer scan threads them through with zero
    stack/unstack copies (they alias straight into the while-loop carry)."""
    unit, reps, rest = layer_plan(cfg)
    aux_total = _zero_aux()

    # -- scanned repetitions --
    def unit_fn(x, per_rep):
        p_list, s_list = per_rep
        new_states, aux = [], _zero_aux()
        x = constrain_batch(x)  # keep the batch pinned to DP through the scan
        for j, kind in enumerate(unit):
            s = s_list[j] if s_list is not None else None
            x, ns, a = apply_block(kind, p_list[j], cfg, x, q_pos, s,
                                   cache_index, image_embeds, train)
            new_states.append(ns)
            aux = jax.tree.map(jnp.add, aux, a)
        return x, (new_states, aux)

    scan_states = states["scan"] if states is not None else None
    body = _maybe_remat(unit_fn, cfg) if train else unit_fn
    x, (new_scan_states, auxs) = jax.lax.scan(
        body, x, (params["scan"], scan_states))
    aux_total = jax.tree.map(lambda t, a: t + a.sum(), aux_total, auxs)

    # -- remainder layers (unrolled) --
    new_rest_states = []
    for i, kind in enumerate(rest):
        s = states["rest"][i] if states is not None else None
        x, ns, a = apply_block(kind, params["rest"][i], cfg, x, q_pos, s,
                               cache_index, image_embeds, train)
        new_rest_states.append(ns)
        aux_total = jax.tree.map(jnp.add, aux_total, a)

    new_states = None
    if states is not None:
        new_states = dict(states, scan=new_scan_states, rest=new_rest_states)
    return x, new_states, aux_total


def embed_inputs(params, cfg: ModelConfig, tokens):
    if cfg.embed_inputs:
        x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    else:
        x = tokens.astype(jnp.dtype(cfg.dtype))  # precomputed frame/patch embeds
    return constrain_batch(x)


def lm_head(params, cfg: ModelConfig, x):
    w = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = pim_linear(x, w, cfg=cfg.pim).astype(jnp.float32)
    if cfg.logits_softcap:
        logits = jnp.tanh(logits / cfg.logits_softcap) * cfg.logits_softcap
    return logits


def forward(params, cfg: ModelConfig, tokens, image_embeds=None, train=False):
    """Full-sequence forward. Returns (logits (B,S,V) f32, aux loss)."""
    x = embed_inputs(params, cfg, tokens)
    b, s = x.shape[:2]
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _, aux = _run_blocks(params, cfg, x, q_pos, image_embeds=image_embeds,
                            train=train)
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    return lm_head(params, cfg, x), aux["loss"]


def _xent(logits, labels):
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def loss_fn(params, cfg: ModelConfig, batch, train=True):
    """Mean next-token cross entropy (+ MoE aux). batch: tokens/labels(+images).

    ``cfg.loss_chunk > 0`` evaluates the head + xent in sequence chunks so
    the (B, S, V) logits tensor never materializes (big-vocab memory fix).
    """
    tokens = batch["tokens"]
    labels = batch["labels"]
    x = embed_inputs(params, cfg, tokens)
    b, s = x.shape[:2]
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _, aux = _run_blocks(params, cfg, x, q_pos,
                            image_embeds=batch.get("image_embeds"), train=train)
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)

    if cfg.loss_chunk and s % cfg.loss_chunk == 0 and s > cfg.loss_chunk:
        n_chunk = s // cfg.loss_chunk
        xc = x.reshape(b, n_chunk, cfg.loss_chunk, -1).swapaxes(0, 1)
        lc = labels.reshape(b, n_chunk, cfg.loss_chunk).swapaxes(0, 1)

        def chunk_loss(carry, xl):
            xi, li = xl
            logits = lm_head(params, cfg, xi)
            return carry + _xent(logits, li).sum(), None

        total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (xc, lc))
        loss = total / (b * s)
    else:
        logits = lm_head(params, cfg, x)
        loss = _xent(logits, labels).mean()
    return loss + aux["loss"]


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, tokens, state, image_embeds=None,
                return_stats=False):
    """One decode step. tokens (B, 1) (or (B,1,d) embeds) -> (logits, state).

    ``state["length"]`` is (B,): every slot of a continuous-batching grid
    decodes against its own position/offset.

    ``return_stats`` (static) appends a per-step telemetry dict —
    ``moe_drop_frac``, the fraction of this step's top-k routing
    assignments dropped at capacity, averaged over MoE layers (0.0 for
    dense models) — which the engine feeds into its ``stats()`` ring
    buffers."""
    x = embed_inputs(params, cfg, tokens)
    b = x.shape[0]
    idx = jnp.broadcast_to(state["length"], (b,)).astype(jnp.int32)
    q_pos = idx[:, None]
    x, new_state, aux = _run_blocks(params, cfg, x, q_pos, states=state,
                                    cache_index=idx, image_embeds=image_embeds)
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params, cfg, x)
    new_state["length"] = state["length"] + 1
    if return_stats:
        stats = {"moe_drop_frac": aux["drop"]
                 / jnp.maximum(aux["layers"], 1.0)}
        return logits, new_state, stats
    return logits, new_state


def prefill(params, cfg: ModelConfig, tokens, state, image_embeds=None):
    """Run a whole prompt through the model, filling the decode state."""
    x = embed_inputs(params, cfg, tokens)
    b, s = x.shape[:2]
    idx = jnp.broadcast_to(state["length"], (b,)).astype(jnp.int32)
    q_pos = idx[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
    x, new_state, _ = _run_blocks(params, cfg, x, q_pos, states=state,
                                  cache_index=idx, image_embeds=image_embeds)
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params, cfg, x[:, -1:])
    new_state["length"] = state["length"] + s
    return logits, new_state


def init_state(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Decode state; KV caches default to the model compute dtype."""
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    return C.init_model_state(cfg, batch, max_len, dtype=dtype)


# ---------------------------------------------------------------------------
# Slot-addressed prefill (continuous-batching admission path)
# ---------------------------------------------------------------------------
# The decode-state grid puts the batch axis at position 1 for scan-stacked
# leaves ((n_reps, B, ...)) and position 0 for remainder-layer leaves and
# ``length`` — fixed by ``cache.init_model_state``'s construction, so slot
# addressing needs no per-leaf shape sniffing.

def _slot_take(state, slot):
    """Slice slot ``slot`` out of a (max_batch, ...) grid as a batch-1 state."""
    def sl(ax):
        return lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=ax)
    return {
        "scan": [jax.tree.map(sl(1), t) for t in state["scan"]],
        "rest": [jax.tree.map(sl(0), t) for t in state["rest"]],
        "length": jax.lax.dynamic_slice_in_dim(state["length"], slot, 1, axis=0),
    }


def _slot_put(state, s1, slot):
    """Write a batch-1 state back into slot ``slot`` of the grid."""
    def pu(ax):
        return lambda big, small: jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, axis=ax)
    return dict(
        state,
        scan=[jax.tree.map(pu(1), bt, st)
              for bt, st in zip(state["scan"], s1["scan"])],
        rest=[jax.tree.map(pu(0), bt, st)
              for bt, st in zip(state["rest"], s1["rest"])],
        length=pu(0)(state["length"], s1["length"]),
    )


def prefill_into_slot(params, cfg: ModelConfig, tokens, state, slot, start_pos):
    """Prefill ``tokens`` (1, S) into slot ``slot`` of a decode-state grid.

    Jit-safe (``slot``/``start_pos`` are traced scalars — one compiled
    variant per chunk length S, not per slot or position) and donation-safe:
    the grid updates are ``dynamic_update_slice``s, so under
    ``donate_argnums`` XLA writes the slot in place instead of copying the
    full (max_batch, max_len) state. Returns (last-token logits (1, 1, V),
    updated grid). Chunked admission calls this once per power-of-two chunk
    of the prompt, threading ``start_pos`` forward.

    Mesh-sharded serving: the grid's batch axis shards on "data" and the
    batch-1 slot slice/put crosses shards — GSPMD gathers here, which is
    fine on the admission path. What must stay exact is the *returned*
    grid's layout: the engine pins it with ``out_shardings`` equal to the
    donated input shardings, so repeated admissions and the decode hot loop
    see one stable layout and steady state never reshards (DESIGN.md §5).
    """
    s1 = _slot_take(state, slot)
    # Slot reuse must not leak the previous occupant's state into the new
    # request: KV rows are position-masked (a fresh slot's length restarts
    # at 0, so stale rows are never attendable before they are overwritten)
    # but recurrent carries (RG-LRU h/conv, RWKV wkv/shifts) and ring
    # buffers are position-less — zero every leaf on a request's FIRST
    # chunk (start_pos == 0; later chunks continue the carried state).
    fresh = jnp.asarray(start_pos, jnp.int32) == 0

    def clear(leaf):
        return jnp.where(fresh, jnp.zeros((), leaf.dtype), leaf)

    s1 = {
        "scan": [jax.tree.map(clear, t) for t in s1["scan"]],
        "rest": [jax.tree.map(clear, t) for t in s1["rest"]],
        "length": jnp.reshape(jnp.asarray(start_pos, jnp.int32), (1,)),
    }
    logits, s1 = prefill(params, cfg, tokens, s1)
    return logits, _slot_put(state, s1, slot)
