"""Normalization layers (RMSNorm / LayerNorm / QK-norm), pure functions.

Params are plain dicts; compute in f32 then cast back — the standard
numerics discipline for bf16 training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


def layernorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def init_norm(kind: str, d: int):
    return init_layernorm(d) if kind == "layernorm" else init_rmsnorm(d)


def apply_norm(kind: str, p, x, eps: float = 1e-6):
    return layernorm(p, x, eps) if kind == "layernorm" else rmsnorm(p, x, eps)


def qk_head_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm over head_dim (qwen3-style qk_norm).

    ``x``: (..., heads, head_dim); ``scale``: (head_dim,).
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)
