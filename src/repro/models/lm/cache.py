"""Decode-time state: KV caches and recurrent states, as plain pytrees.

Every layer kind owns a state factory + a functional update; ``serve_step``
threads the whole-state pytree through ``jax.jit`` so the cache lives
device-resident across steps (the serving engine never materializes it on
host). Shapes are static — ``length`` is a traced scalar index.

Hybrid/SSM archs keep O(1) decode state (the point of running them at the
500k shape); local attention keeps a ring buffer of ``window`` tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, kv_len: int | None = None,
                  dtype=jnp.bfloat16, force_float: bool = False):
    n = kv_len if kv_len is not None else max_len
    shape = (batch, n, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_quant and not force_float:
        return {"k": jnp.zeros(shape, jnp.int8), "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def quantize_kv(x: jax.Array):
    """Symmetric per-(token, head) int8 codes + f32 scales.

    x (B, S, H, D) -> (codes int8, scale (B, S, H))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = amax / 127.0 + 1e-30
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def update_kv_cache(cache, k_new, v_new, index):
    """Insert (B, S_new, H, D) at per-sequence offsets along the time axis.

    ``index`` is (B,) (continuous batching: every slot has its own length)
    or a scalar (uniform). Scatter-based so slots at different positions
    coexist in one decode grid."""
    from repro.distributed.sharding import constrain_kv_update

    b, s_new = k_new.shape[:2]
    k_new = constrain_kv_update(k_new)
    v_new = constrain_kv_update(v_new)
    if s_new == cache["k"].shape[1]:
        # Full-length write (prefill into a same-length cache, index 0):
        # replace outright — a dynamic scatter here makes GSPMD all-gather
        # the seq-sharded cache (measured 0.24 TB/chip on prefill cells).
        if "k_scale" in cache:
            kq, ks = quantize_kv(k_new)
            vq, vs = quantize_kv(v_new)
            return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        return {"k": k_new.astype(cache["k"].dtype),
                "v": v_new.astype(cache["v"].dtype)}
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32), (b,))
    rows = idx[:, None] + jnp.arange(s_new, dtype=jnp.int32)[None, :]
    bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
    if "k_scale" in cache:  # int8 KV: quantize the update, store scales
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        return {
            "k": cache["k"].at[bidx, rows].set(kq, unique_indices=True),
            "v": cache["v"].at[bidx, rows].set(vq, unique_indices=True),
            "k_scale": cache["k_scale"].at[bidx, rows].set(ks, unique_indices=True),
            "v_scale": cache["v_scale"].at[bidx, rows].set(vs, unique_indices=True),
        }
    k = cache["k"].at[bidx, rows].set(k_new.astype(cache["k"].dtype),
                                      unique_indices=True)
    v = cache["v"].at[bidx, rows].set(v_new.astype(cache["v"].dtype),
                                      unique_indices=True)
    return {"k": k, "v": v}


def init_ring_cache(cfg: ModelConfig, batch: int, window: int, dtype=jnp.bfloat16):
    """Sliding-window KV ring buffer for local_attn blocks (O(window) state).

    Stays float: the window is small and ring slots rewrite constantly."""
    return init_kv_cache(cfg, batch, window, dtype=dtype, force_float=True)


def update_ring_cache(cache, k_new, v_new, index):
    """Write (B, 1, H, D) at per-sequence slot ``index % window`` (decode)."""
    b = k_new.shape[0]
    window = cache["k"].shape[1]
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32), (b,))
    slot = jnp.mod(idx, window)[:, None]
    bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
    k = cache["k"].at[bidx, slot].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[bidx, slot].set(v_new.astype(cache["v"].dtype))
    return {"k": k, "v": v}


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),  # recurrence in f32
    }


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    heads = cfg.d_model // cfg.rwkv_head_dim
    return {
        "tm_shift": jnp.zeros((batch, cfg.d_model), dtype),   # last token (time-mix)
        "cm_shift": jnp.zeros((batch, cfg.d_model), dtype),   # last token (channel-mix)
        "wkv": jnp.zeros((batch, heads, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32),
    }


def init_layer_state(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     n_image_tokens: int = 0, dtype=jnp.bfloat16):
    if kind == "attn":
        return init_kv_cache(cfg, batch, max_len, dtype=dtype)
    if kind == "local_attn":
        return init_ring_cache(cfg, batch, min(cfg.local_window or max_len, max_len), dtype=dtype)
    if kind == "cross_attn":
        # image KV is written once and reused — quantization buys nothing
        return init_kv_cache(cfg, batch, n_image_tokens or cfg.n_image_tokens,
                             dtype=dtype, force_float=True)
    if kind == "rglru":
        return init_rglru_state(cfg, batch)
    if kind == "rwkv":
        return init_rwkv_state(cfg, batch)
    raise ValueError(kind)


def init_model_state(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Full decode state, shaped for the scan-over-units execution.

    ``scan``: one stacked tree per unit position — leaves carry a leading
    (n_reps,) axis so the layer scan consumes/produces them with NO
    stack/unstack copies (those copies dominated decode HBM traffic before;
    see EXPERIMENTS.md §Perf/llama-decode). ``rest``: per-layer states for
    the unrolled remainder. ``length`` is (B,): every continuous-batching
    slot decodes at its own position."""
    from .model import layer_plan  # local import to avoid a cycle

    unit, reps, rest = layer_plan(cfg)

    def stacked(kind):
        proto = init_layer_state(kind, cfg, batch, max_len, dtype=dtype)
        return jax.tree.map(
            lambda l: jnp.zeros((reps,) + l.shape, l.dtype), proto)

    return {
        "scan": [stacked(kind) for kind in unit],
        "rest": [init_layer_state(kind, cfg, batch, max_len, dtype=dtype)
                 for kind in rest],
        "length": jnp.zeros((batch,), jnp.int32),
    }
