"""RWKV-6 "Finch" block (arXiv:2404.05892) — attention-free, data-dependent
decay linear recurrence.

Time-mix per head (head_dim D, state S in f32, key-major layout):

    y_t = r_t @ (S_{t-1} + (u * k_t) v_t^T)          readout
    S_t = diag(w_t) S_{t-1} + k_t v_t^T              state update

with the Finch novelty: the per-channel decay is data-dependent,
``w_t = exp(-exp(w0 + tanh(x_w @ A) @ B))``, and token-shift interpolation
``lerp(x_t, x_{t-1}, mu)`` feeds each projection. The channel-mix half is
the squared-ReLU gated FFN of the RWKV line.

Prefill runs a chunked ``jax.lax.scan`` (sequential over time but fully
parallel over batch x heads x channels — the dominant cost is the rank-1
state update, S-independent per step); decode is the O(1) step. The state
is (H, D, D) per sequence — constant in sequence length, which is what
qualifies this family for the 500k decode shape.

A chunkwise-parallel Pallas kernel for the prefill scan is a perf-phase
candidate (see EXPERIMENTS.md §Perf); the scan here is the reference
semantics the kernel must reproduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pim_layers import pim_linear

from .config import ModelConfig

_LORA = 64  # decay-LoRA rank (Finch uses 64 for ~3B models)


def init_rwkv_block(cfg: ModelConfig, key):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    heads = d // hd
    ks = jax.random.split(key, 8)
    s = d**-0.5
    # Decay base: initialized so channels span slow..fast decay (RWKV init).
    ratio = jnp.arange(d, dtype=jnp.float32) / max(d - 1, 1)
    w0 = -6.0 + 5.0 * ratio**0.9
    return {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),  # token-shift for r,k,v,g,w
        "w_r": jax.random.normal(ks[0], (d, d), jnp.float32) * s,
        "w_k": jax.random.normal(ks[1], (d, d), jnp.float32) * s,
        "w_v": jax.random.normal(ks[2], (d, d), jnp.float32) * s,
        "w_g": jax.random.normal(ks[3], (d, d), jnp.float32) * s,
        "w_o": jax.random.normal(ks[4], (d, d), jnp.float32) * s,
        "decay_a": jax.random.normal(ks[5], (d, _LORA), jnp.float32) * s,
        "decay_b": jax.random.normal(ks[6], (_LORA, d), jnp.float32) * _LORA**-0.5,
        "w0": w0,
        "u": jnp.zeros((heads, hd), jnp.float32),   # bonus for current token
        "ln_scale": jnp.ones((heads, hd), jnp.float32),  # per-head groupnorm
    }


def init_rwkv_channel_mix(cfg: ModelConfig, key):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d), jnp.float32),  # token-shift for k, r
        "w_k": jax.random.normal(ks[0], (d, f), jnp.float32) * d**-0.5,
        "w_v": jax.random.normal(ks[1], (f, d), jnp.float32) * f**-0.5,
        "w_r": jax.random.normal(ks[2], (d, d), jnp.float32) * d**-0.5,
    }


_LOG_W_MIN = -5.0  # decay clamp: keeps exp(-P) < e^80 within a 16-chunk


def _chunked_wkv(r, k, v, w, u, S0, L: int):
    """Chunked-parallel WKV: matmul form within chunks, O(S/L) state updates.

    The sequential scan touches the (B,H,D,D) state every token — HBM
    traffic ~ S x D^2. Rewriting over chunks of L tokens turns the
    intra-chunk part into three (L x D)-matmuls per head (MXU work) and
    updates the state once per chunk (traffic / L). Exactness: with
    P[t] = cumsum(log w), every decay product becomes exp(P_i - P_j); the
    log-decay clamp at -5 bounds exp magnitudes inside f32 for L = 16
    (channels decaying faster than e^-5/step forget within a token anyway).

    r,k,v (B,S,H,D) f32; w (B,S,H,D) in (0,1); S0 (B,H,D,D). Returns
    (y (B,S,H,D), S_final).
    """
    bsz, s, h, d = r.shape
    n = s // L

    def to_chunks(t):  # (B,S,H,D) -> (n, B, H, L, D)
        return t.reshape(bsz, n, L, h, d).transpose(1, 0, 3, 2, 4)

    lw = jnp.maximum(jnp.log(jnp.clip(w, 1e-38)), _LOG_W_MIN)
    rc, kc, vc, lwc = map(to_chunks, (r, k, v, lw))
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)          # strict: s < t
    u_b = u[None, :, None, :]                              # (1, H, 1, D)

    def chunk_step(S, xs):
        rj, kj, vj, lwj = xs                               # (B,H,L,D)
        P = jnp.cumsum(lwj, axis=2)                        # inclusive
        p_prev = P - lwj                                   # P[t-1]
        r_t = rj * jnp.exp(p_prev)                         # <= |r|
        k_t = kj * jnp.exp(-P)                             # <= |k| e^{5L}
        A = jnp.einsum("bhtd,bhsd->bhts", r_t, k_t)
        A = jnp.where(mask, A, 0.0)
        y = jnp.einsum("bhtd,bhdv->bhtv", r_t, S)          # carry-in term
        y += jnp.einsum("bhts,bhsv->bhtv", A, vj)          # intra-chunk
        y += jnp.sum(rj * u_b * kj, -1, keepdims=True) * vj  # u-bonus diag
        decay_all = jnp.exp(P[:, :, -1:, :])               # Π_chunk w
        k_rem = kj * jnp.exp(P[:, :, -1:, :] - P)          # exp(P_L - P_s)
        S = (decay_all[:, :, 0, :, None] * S
             + jnp.einsum("bhsd,bhsv->bhdv", k_rem, vj))
        return S, y

    S_last, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, lwc))
    # (n, B, H, L, D) -> (B, S, H, D)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(bsz, s, h, d)
    return y, S_last


def _token_shift(x: jax.Array, prev: jax.Array | None):
    """x (B,S,d) -> x_{t-1} (B,S,d); ``prev`` (B,d) carries across calls."""
    first = prev[:, None].astype(x.dtype) if prev is not None else jnp.zeros_like(x[:, :1])
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _heads(x, heads, hd):
    return x.reshape(*x.shape[:-1], heads, hd)


def _group_norm(x, scale, eps):
    """Per-head RMS-style groupnorm over head_dim; x (..., H, D) f32."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rwkv_time_mix(p, cfg: ModelConfig, x: jax.Array, state: dict | None = None,
                  train: bool = False):
    """x (B,S,d) -> (y (B,S,d), new_state). f32 recurrence, scan over S."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    heads = d // hd
    prev_tok = state["tm_shift"] if state is not None else None
    xp = _token_shift(x, prev_tok)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + (xp - x) * mu[i] for i in range(5))

    r = _heads(pim_linear(xr, p["w_r"], cfg=cfg.pim, train=train), heads, hd)
    k = _heads(pim_linear(xk, p["w_k"], cfg=cfg.pim, train=train), heads, hd)
    v = _heads(pim_linear(xv, p["w_v"], cfg=cfg.pim, train=train), heads, hd)
    g = jax.nn.silu(pim_linear(xg, p["w_g"], cfg=cfg.pim, train=train))
    # Data-dependent per-channel decay (the Finch contribution). Log-decay
    # clamped at -5/step (see _chunked_wkv) in both execution paths so the
    # chunked rewrite is exact w.r.t. the sequential scan.
    dd = jnp.tanh(xw.astype(jnp.float32) @ p["decay_a"]) @ p["decay_b"]
    w = jnp.exp(jnp.maximum(-jnp.exp(p["w0"] + dd), _LOG_W_MIN))
    w = _heads(w, heads, hd)

    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    u = p["u"]

    S0 = state["wkv"] if state is not None else jnp.zeros((b, heads, hd, hd), jnp.float32)
    chunk = cfg.rwkv_chunk
    if chunk and s % chunk == 0 and s > 1:
        y, S_last = _chunked_wkv(r32, k32, v32, w, u, S0, chunk)
    else:
        def step(S, inp):
            r_t, k_t, v_t, w_t = inp                      # (B,H,D) each
            kv = k_t[..., :, None] * v_t[..., None, :]    # (B,H,D,D) rank-1
            y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[..., :, None] * kv)
            S = w_t[..., :, None] * S + kv
            return S, y

        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r32, k32, v32, w))
        S_last, ys = jax.lax.scan(step, S0, xs)
        y = jnp.moveaxis(ys, 0, 1)                        # (B,S,H,D)

    y = _group_norm(y, p["ln_scale"], cfg.norm_eps) * g.astype(jnp.float32).reshape(
        b, s, heads, hd)
    out = pim_linear(y.reshape(b, s, d).astype(x.dtype), p["w_o"], cfg=cfg.pim,
                     train=train, role="tp_in")
    new_state = None
    if state is not None:
        new_state = dict(state, tm_shift=x[:, -1].astype(jnp.float32), wkv=S_last)
    return out, new_state


def rwkv_channel_mix(p, cfg: ModelConfig, x: jax.Array, state: dict | None = None,
                     train: bool = False):
    prev_tok = state["cm_shift"] if state is not None else None
    xp = _token_shift(x, prev_tok)
    mu = p["mu"].astype(x.dtype)
    xk = x + (xp - x) * mu[0]
    xr = x + (xp - x) * mu[1]
    k = pim_linear(xk, p["w_k"], cfg=cfg.pim, train=train)
    k = jnp.square(jax.nn.relu(k))
    v = pim_linear(k, p["w_v"], cfg=cfg.pim, train=train, role="tp_in")
    r = jax.nn.sigmoid(pim_linear(xr, p["w_r"], cfg=cfg.pim, train=train))
    out = r * v
    new_state = dict(state, cm_shift=x[:, -1].astype(jnp.float32)) if state is not None else None
    return out, new_state
