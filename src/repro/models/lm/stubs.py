"""Modality-frontend stubs for the [vlm]/[audio] backbones.

Per the assignment, the transformer BACKBONE is the deliverable; the
frontend is a stub that supplies precomputed patch/frame embeddings with
the right shapes and deterministic content. ``input_specs()`` in
:mod:`repro.launch.dryrun` references these shapes; examples/tests call the
generators for real arrays.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def image_patch_embeddings(cfg: ModelConfig, batch: int, key=None, dtype=jnp.bfloat16):
    """Stub ViT output: (B, n_image_tokens, d_model)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    shape = (batch, cfg.n_image_tokens, cfg.d_model)
    return (jax.random.normal(key, shape, jnp.float32) * cfg.d_model**-0.5).astype(dtype)


def audio_frame_embeddings(cfg: ModelConfig, batch: int, seq: int, key=None,
                           dtype=jnp.bfloat16):
    """Stub EnCodec frame embeddings: (B, S, d_model) — musicgen's decoder
    input after the codebook-sum embedding stage."""
    key = key if key is not None else jax.random.PRNGKey(1)
    shape = (batch, seq, cfg.d_model)
    return (jax.random.normal(key, shape, jnp.float32) * cfg.d_model**-0.5).astype(dtype)
