"""Grouped-query attention with the variants the assigned archs need.

Covers: MHA/GQA (any kv:q ratio), QKV bias (qwen1.5), per-head qk_norm
(qwen3), sliding-window local attention (recurrentgemma), cross-attention
over stub image embeddings (llama-3.2-vision), attention-logit softcap
(grok), and the shared prefill/decode code path driven by explicit position
tensors.

All projections route through :func:`repro.core.pim_layers.pim_linear`, so
an arch config with ``pim`` set executes every QKVO matmul through the
paper's bit-serial pipeline (Eq. 1) — that is the integration point of the
NAND-SPIN technique into the LM stack.

Softmax runs in f32 with the usual max-subtraction; masked positions get
``NEG`` rather than -inf so fully-masked rows (ring-buffer slots not yet
written) produce zeros, not NaNs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pim_layers import pim_linear

from .config import ModelConfig
from .norms import qk_head_norm
from .rope import apply_rope

NEG = -2.0**30


def init_attention(cfg: ModelConfig, key, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, hq * hd), jnp.float32) * scale,
        "wk": jax.random.normal(ks[1], (d, hkv * hd), jnp.float32) * scale,
        "wv": jax.random.normal(ks[2], (d, hkv * hd), jnp.float32) * scale,
        "wo": jax.random.normal(ks[3], (hq * hd, d), jnp.float32) * (hq * hd) ** -0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    if cross:
        # cross-attn gate (llama-vision zero-init tanh gate)
        p["gate"] = jnp.zeros((), jnp.float32)
    return p


def attention_mask(q_pos: jax.Array, kv_pos: jax.Array, window: int = 0,
                   causal: bool = True) -> jax.Array:
    """(B, Sq), (B, Skv) int32 -> (B, 1, Sq, Skv) bool (True = attend)."""
    q = q_pos[:, :, None]
    k = kv_pos[:, None, :]
    m = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        m &= k <= q
    if window:
        m &= k > q - window
    return m[:, None, :, :]


def gqa_scores_softmax_v(q, k, v, mask, softcap: float = 0.0,
                         k_scale=None, v_scale=None):
    """Core GQA attention. q (B,Sq,Hq,D), k/v (B,Skv,Hkv,D), mask (B,1,Sq,Skv).

    K/V stay in their storage dtype through the einsums (f32 accumulation
    via preferred_element_type); materializing an f32 copy of a 32k-token
    cache costed 3x the decode memory floor (§Perf/llama-decode). Softmax
    runs in f32; probabilities cast back to the value dtype for the PV
    contraction (MXU-native layout).

    int8 KV caches pass per-(token, head) ``k_scale``/``v_scale``
    ((B, Skv, Hkv) f32): scales fold into the score tensor and the
    probabilities respectively, so a dequantized cache never materializes.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    qg = (q.astype(jnp.float32) * d**-0.5)
    if jnp.issubdtype(k.dtype, jnp.floating):
        qg = qg.astype(k.dtype)
    qg = qg.reshape(b, sq, hkv, g, d)
    # scores: (B, Hkv, G, Sq, Skv), accumulated in f32
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    if k_scale is not None:  # (B, Skv, Hkv) -> (B, Hkv, 1, 1, Skv)
        s = s * k_scale.transpose(0, 2, 1)[:, :, None, None, :]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask[:, :, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p = (p * v_scale.transpose(0, 2, 1)[:, :, None, None, :]).astype(q.dtype)
    elif jnp.issubdtype(v.dtype, jnp.floating):
        p = p.astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, sq, hq, d).astype(q.dtype)


def attention(
    p,
    cfg: ModelConfig,
    x: jax.Array,                 # (B, Sq, d)
    q_pos: jax.Array,             # (B, Sq)
    kv_src: jax.Array | None = None,   # cross-attn memory (B, Skv, d)
    cache: dict | None = None,    # KV cache dict (decode / ring)
    cache_index: jax.Array | None = None,
    window: int = 0,
    causal: bool = True,
    ring: bool = False,
    train: bool = False,
):
    """One attention block. Returns (out (B,Sq,d), updated_cache | None)."""
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, sq, _ = x.shape
    pim = cfg.pim

    q = pim_linear(x, p["wq"], p.get("bq"), cfg=pim, train=train)
    q = q.reshape(b, sq, hq, hd)
    if kv_src is None:
        kv_in = x
        kv_pos_new = q_pos
    else:
        kv_in = kv_src
        kv_pos_new = jnp.broadcast_to(
            jnp.arange(kv_src.shape[1], dtype=jnp.int32)[None], (b, kv_src.shape[1]))
    k = pim_linear(kv_in, p["wk"], p.get("bk"), cfg=pim, train=train)
    v = pim_linear(kv_in, p["wv"], p.get("bv"), cfg=pim, train=train)
    k = k.reshape(b, kv_in.shape[1], hkv, hd)
    v = v.reshape(b, kv_in.shape[1], hkv, hd)

    if cfg.qk_norm:
        q = qk_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = qk_head_norm(p["k_norm"], k, cfg.norm_eps)
    if kv_src is None:  # RoPE only for self-attention
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, kv_pos_new, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        from . import cache as C

        if kv_src is not None:
            # Cross-attn: image KV computed once at prefill, then reused.
            if cache_index is None:
                write = jnp.ones((b,), bool)
            else:
                write = jnp.broadcast_to(cache_index == 0, (b,))
            new_cache = jax.tree.map(
                lambda old, new: jnp.where(
                    write.reshape((b,) + (1,) * (old.ndim - 1)),
                    new.astype(old.dtype), old),
                cache, {"k": k, "v": v})
            k, v = new_cache["k"], new_cache["v"]
            kv_pos = jnp.broadcast_to(
                jnp.arange(k.shape[1], dtype=jnp.int32)[None], (b, k.shape[1]))
            mask = jnp.ones((b, 1, sq, k.shape[1]), bool)  # attend to all image tokens
        elif ring:
            wsize = cache["k"].shape[1]
            if sq == 1:
                new_cache = C.update_ring_cache(cache, k, v, cache_index)
                k, v = new_cache["k"], new_cache["v"]
                slot = jnp.arange(wsize, dtype=jnp.int32)[None]
                # Slot s holds the largest position p <= index with p % w == s.
                idx = jnp.broadcast_to(cache_index, (b,))[:, None] + sq - 1
                kv_pos = idx - jnp.mod(idx - slot, wsize)   # (B, wsize)
                mask = attention_mask(q_pos, kv_pos, window=window, causal=causal)
                mask &= (kv_pos[:, None, None, :] >= 0)
            else:
                # Prefill: scatter the last `wsize` chunk tokens into their
                # p % w slots, and attend over both the in-chunk tokens and
                # the already-cached window — chunked prefill (serving's
                # power-of-two prompt buckets) starts chunks at offsets > 0,
                # so the window can reach back across the chunk boundary.
                # Cached slots with a derived position < 0 were never
                # written (fresh cache / short history) and are masked.
                take = min(wsize, sq)
                slots = q_pos[:, -take:] % wsize
                bidx = jnp.arange(b)[:, None]
                new_cache = {
                    "k": cache["k"].at[bidx, slots].set(k[:, -take:].astype(cache["k"].dtype)),
                    "v": cache["v"].at[bidx, slots].set(v[:, -take:].astype(cache["v"].dtype)),
                }
                start = (jnp.broadcast_to(cache_index, (b,))[:, None]
                         if cache_index is not None else
                         jnp.zeros((b, 1), jnp.int32))
                slot_ids = jnp.arange(wsize, dtype=jnp.int32)[None]
                prev = start - 1   # last position before this chunk
                cached_pos = prev - jnp.mod(prev - slot_ids, wsize)  # (B, w)
                k = jnp.concatenate([cache["k"].astype(k.dtype), k], axis=1)
                v = jnp.concatenate([cache["v"].astype(v.dtype), v], axis=1)
                kv_pos = jnp.concatenate([cached_pos, q_pos], axis=1)
                mask = attention_mask(q_pos, kv_pos, window=window, causal=causal)
                mask &= (kv_pos[:, None, None, :] >= 0)
        else:
            new_cache = C.update_kv_cache(cache, k, v, cache_index)
            k, v = new_cache["k"], new_cache["v"]
            kv_pos = jnp.broadcast_to(
                jnp.arange(k.shape[1], dtype=jnp.int32)[None], (b, k.shape[1]))
            mask = attention_mask(q_pos, kv_pos, window=window, causal=causal)
            valid = jnp.broadcast_to(cache_index, (b,))[:, None] + sq  # (B, 1)
            mask &= (kv_pos < valid)[:, None, None, :]
    else:
        kv_pos = kv_pos_new
        mask = attention_mask(q_pos, kv_pos, window=window,
                              causal=causal and kv_src is None)

    scales = {}
    if new_cache is not None and "k_scale" in new_cache:
        scales = {"k_scale": new_cache["k_scale"],
                  "v_scale": new_cache["v_scale"]}
    o = gqa_scores_softmax_v(q, k, v, mask, softcap=cfg.attn_softcap, **scales)
    out = pim_linear(o.reshape(b, sq, hq * hd), p["wo"], cfg=pim, train=train,
                     role="tp_in")
    if "gate" in p:  # zero-init cross-attn gate
        out = jnp.tanh(p["gate"]).astype(out.dtype) * out
    return out, new_cache
