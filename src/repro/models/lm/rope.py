"""Rotary position embeddings, decode-aware.

``apply_rope(x, positions, theta)`` works for both full-sequence prefill
(positions = arange) and single-token decode (positions = cache length), so
train_step and serve_step share one code path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (B, S) int32 -> (sin, cos) of shape (B, S, head_dim/2) f32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (B, S, half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """x: (B, S, H, D) -> rotated, same shape/dtype. Rotation in f32."""
    b, s, h, d = x.shape
    sin, cos = rope_angles(positions, d, theta)
    sin = sin[:, :, None, :]  # (B, S, 1, D/2)
    cos = cos[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
