"""LM substrate: configs, blocks and whole-model entry points."""
from .config import ModelConfig, MoEConfig
from .model import (
    abstract_params,
    decode_step,
    forward,
    init,
    init_state,
    layer_plan,
    loss_fn,
    param_count,
    prefill,
    prefill_into_slot,
    prepack_params,
)

__all__ = [
    "ModelConfig", "MoEConfig", "abstract_params", "decode_step", "forward",
    "init", "init_state", "layer_plan", "loss_fn", "param_count", "prefill",
    "prefill_into_slot", "prepack_params",
]
