"""Model zoo: the paper's CNN workloads + the assigned LM architectures."""
